//! `scale-sim` CLI — the leader entrypoint (Fig 1): config + topology in,
//! traces + summary reports out, plus sweep / validate / artifact
//! subcommands. Argument parsing is hand-rolled (clap is unavailable in
//! the offline build). Every subcommand drives the [`scale_sim::engine`]
//! façade; error plumbing uses `Box<dyn Error>` (anyhow is unavailable
//! offline).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use scale_sim::config::{workloads, ArchConfig, Topology};
use scale_sim::engine::{BackendKind, Engine};
use scale_sim::runtime::{default_artifact_dir, Runtime};
use scale_sim::util::fmt_bytes;
use scale_sim::{sweep, Dataflow, LayerShape};

const USAGE: &str = "\
scale-sim — systolic CNN accelerator simulator (SCALE-Sim reproduction)

USAGE:
  scale-sim run [-c cfg] [-t topology] [-o outdir] [--dataflow os|ws|is]
                [--array RxC] [--backend analytical|trace|rtl]
                [--dump-traces] [--functional TILE] [--threads N]
      Simulate a topology (built-in name like `resnet50`/`W5`, or a csv
      path). Writes compute/sram/dram/energy reports when -o is given.

  scale-sim sweep <dataflow|memory|shape> [-t topology]...
      Reproduce the paper's design-space sweeps on the MLPerf suite
      (Figs 5-8 series printed as tables) through the memoizing engine
      grid; writes BENCH_sweep.json (wall-clock + cache hit-rate).

  scale-sim validate [--max N]
      Fig 4: run every engine backend (analytical, trace-driven, RTL
      PE-grid) on array-sized matmuls through the same Engine entry
      point; cycle counts must tally exactly.

  scale-sim analyze [-t topology] [--array RxC] [--dataflow os|ws|is]
      Deep-dive one workload: per-layer SRAM bank requirement (§IV-B),
      best dataflow per layer (flexible-dataflow study), and the DRAM
      bandwidth to provision for <5%% slowdown (§III-D stall model).

  scale-sim workloads
      List the built-in MLPerf workloads (Table III).

  scale-sim artifacts
      Show the functional-runtime platform and the AOT artifacts
      available for the functional path.
";

type CliResult<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn fail<T>(msg: String) -> CliResult<T> {
    Err(msg.into())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> CliResult<()> {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("workloads") => cmd_workloads(),
        Some("artifacts") => cmd_artifacts(),
        Some("-h") | Some("--help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => fail(format!("unknown command {other:?}\n{USAGE}")),
    }
}

/// Tiny flag parser: returns value for `--name V` / `-n V`.
struct Args<'a>(&'a [String]);

impl<'a> Args<'a> {
    fn value(&self, long: &str, short: Option<&str>) -> Option<&'a str> {
        let mut it = self.0.iter();
        while let Some(a) = it.next() {
            if a == long || short.is_some_and(|s| a == s) {
                return it.next().map(String::as_str);
            }
        }
        None
    }

    fn flag(&self, long: &str) -> bool {
        self.0.iter().any(|a| a == long)
    }
}

fn load_topology(spec: &str) -> CliResult<Topology> {
    if let Some(t) = workloads::builtin(spec) {
        return Ok(t);
    }
    Ok(Topology::from_file(&PathBuf::from(spec))?)
}

/// Shared `-c/--dataflow/--array` handling for run/analyze.
fn base_config(a: &Args) -> CliResult<ArchConfig> {
    let mut cfg = match a.value("--config", Some("-c")) {
        Some(p) => ArchConfig::from_file(&PathBuf::from(p))?,
        None => ArchConfig::default(),
    };
    if let Some(df) = a.value("--dataflow", None) {
        cfg.dataflow = Dataflow::parse(df)?;
    }
    if let Some(arr) = a.value("--array", None) {
        let (r, c) = arr
            .split_once('x')
            .ok_or("--array expects RxC, e.g. 32x32")?;
        cfg.array_h = r.parse()?;
        cfg.array_w = c.parse()?;
    }
    Ok(cfg)
}

fn cmd_run(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    let cfg = base_config(&a)?;
    let topo = match a.value("--topology", Some("-t")) {
        Some(t) => load_topology(t)?,
        None => match &cfg.topology_path {
            Some(p) => Topology::from_file(p)?,
            None => return fail("no topology: pass -t or set Topology in the cfg".into()),
        },
    };

    let mut b = Engine::builder().config(cfg).dump_traces(a.flag("--dump-traces"));
    if let Some(backend) = a.value("--backend", None) {
        b = b.backend(BackendKind::parse(backend)?);
    }
    if let Some(dir) = a.value("--out", Some("-o")) {
        b = b.out_dir(dir);
    }
    if let Some(t) = a.value("--functional", None) {
        b = b.functional_tile(t.parse()?);
    }
    if let Some(t) = a.value("--threads", None) {
        b = b.threads(t.parse()?);
    }
    let engine = b.build()?;
    let out = engine.run(&topo)?;

    let cfg = engine.cfg();
    let r = &out.report;
    println!(
        "workload {:>14}  dataflow {}  array {}x{}  backend {}",
        r.workload, cfg.dataflow, cfg.array_h, cfg.array_w, engine.backend_kind()
    );
    println!(
        "{:<18} {:>12} {:>8} {:>14} {:>12} {:>10}",
        "layer", "cycles", "util%", "dram_bytes", "avg_rd_bw", "energy_mJ"
    );
    for l in &r.layers {
        println!(
            "{:<18} {:>12} {:>8.2} {:>14} {:>12.4} {:>10.4}",
            l.name(),
            l.timing.cycles,
            l.timing.utilization * 100.0,
            l.dram.total(),
            l.bandwidth.avg_read_bw,
            l.energy.total_mj(),
        );
    }
    println!(
        "TOTAL: {} cycles, {:.2}% util, {} DRAM, {:.4} mJ",
        r.total_cycles(),
        r.overall_utilization(cfg.total_pes()) * 100.0,
        fmt_bytes(r.total_dram().total()),
        r.total_energy().total_mj()
    );
    for (layer, err) in &out.functional {
        println!("functional[{layer}]: max rel err {err:.2e} (AOT artifact vs reference)");
    }
    if !out.files_written.is_empty() {
        println!("wrote {} files under {:?}", out.files_written.len(), out.files_written[0].parent().unwrap());
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    let kind = rest.first().map(String::as_str).unwrap_or("dataflow");
    let topos: Vec<Topology> = match a.value("--topology", Some("-t")) {
        Some(t) => vec![load_topology(t)?],
        None => workloads::mlperf_suite(),
    };
    let engine = Engine::builder().config(ArchConfig::default()).build()?;

    let stats = match kind {
        "dataflow" => {
            let out = engine
                .sweep()
                .workloads(&topos)
                .dataflows(&Dataflow::ALL)
                .square_arrays(&[128, 64, 32, 16, 8])
                .run();
            println!("{:<14} {:>4} {:>6} {:>14} {:>8} {:>12} {:>12}", "workload", "df", "array", "cycles", "util%", "E_comp_mJ", "E_mem_mJ");
            for p in &out.points {
                let e = p.report.total_energy();
                println!(
                    "{:<14} {:>4} {:>6} {:>14} {:>8.2} {:>12.4} {:>12.4}",
                    p.workload,
                    p.dataflow.name(),
                    p.array_h,
                    p.report.total_cycles(),
                    p.report.overall_utilization(p.total_pes()) * 100.0,
                    e.compute_mj,
                    e.memory_mj()
                );
            }
            out.stats
        }
        "memory" => {
            let out = engine
                .sweep()
                .workloads(&topos)
                .sram_sizes_kb(&[32, 64, 128, 256, 512, 1024, 2048])
                .run();
            println!("{:<14} {:>8} {:>14} {:>12}", "workload", "sram_kb", "dram_bytes", "avg_rd_bw");
            for p in &out.points {
                println!(
                    "{:<14} {:>8} {:>14} {:>12.4}",
                    p.workload,
                    p.ifmap_sram_kb,
                    p.report.total_dram().total(),
                    p.report.avg_dram_read_bw()
                );
            }
            out.stats
        }
        "shape" => {
            let out = engine
                .sweep()
                .workloads(&topos)
                .dataflows(&Dataflow::ALL)
                .array_shapes(&sweep::fig8_shapes())
                .run();
            println!("{:<14} {:>4} {:>10} {:>14}", "workload", "df", "shape", "cycles");
            for p in &out.points {
                println!(
                    "{:<14} {:>4} {:>10} {:>14}",
                    p.workload,
                    p.dataflow.name(),
                    format!("{}x{}", p.array_h, p.array_w),
                    p.report.total_cycles()
                );
            }
            out.stats
        }
        other => return fail(format!("unknown sweep {other:?} (dataflow|memory|shape)")),
    };

    let wall_ms = stats.wall.as_secs_f64() * 1e3;
    println!(
        "sweep: {} points in {:.1} ms — {} layer sims, {} cache hits ({:.1}% hit rate)",
        stats.points,
        wall_ms,
        stats.memo.layer_sims,
        stats.memo.cache_hits,
        stats.hit_rate() * 100.0
    );
    stats.write_bench_json(Path::new("BENCH_sweep.json"))?;
    println!("wrote BENCH_sweep.json");
    Ok(())
}

fn cmd_analyze(rest: &[String]) -> CliResult<()> {
    use scale_sim::memory::stall::provision_bandwidth;
    use scale_sim::trace::bank_analysis;

    let a = Args(rest);
    let cfg = base_config(&a)?;
    let topo = load_topology(a.value("--topology", Some("-t")).unwrap_or("resnet50"))?;
    let engine = Engine::builder().config(cfg).build()?;
    let cfg = engine.cfg();

    println!(
        "analyze {} on {}x{} (banks/provision under {}; dataflow column is the per-layer winner)",
        topo.name, cfg.array_h, cfg.array_w, cfg.dataflow
    );
    let flex = engine.flexible_study(&topo);
    println!(
        "{:<18} {:>6} {:>13} {:>13} {:>12} {:>10}",
        "layer", "best", "best_cycles", "operand_banks", "ofmap_banks", "prov_B/cyc"
    );
    for (layer, fl) in topo.layers.iter().zip(&flex.layers) {
        let banks = bank_analysis(cfg.dataflow, layer, cfg);
        let prov = provision_bandwidth(cfg.dataflow, layer, cfg, 0.05);
        println!(
            "{:<18} {:>6} {:>13} {:>13} {:>12} {:>10.1}",
            layer.name,
            fl.best.name(),
            fl.cycles[fl.best as usize],
            banks.operand_banks,
            banks.ofmap_banks,
            prov
        );
    }
    println!(
        "flexible-dataflow speedup: {:.3}x over best fixed, {:.3}x over worst fixed (wins os/ws/is: {:?})",
        flex.speedup_over_best_fixed(),
        flex.speedup_over_worst_fixed(),
        flex.wins()
    );
    Ok(())
}

fn cmd_validate(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    let max: usize = a.value("--max", None).unwrap_or("32").parse()?;
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>6}",
        "size", "rtl_cycles", "trace_cycles", "model_cycles", "match"
    );
    let mut n = 4u64;
    while n as usize <= max {
        let layer = LayerShape::gemm("mm", n, n, n);
        let mut cycles = Vec::new();
        for kind in BackendKind::ALL {
            let engine = Engine::builder()
                .dataflow(Dataflow::Os)
                .array(n, n)
                .backend(kind)
                .build()?;
            cycles.push(engine.run_layer(&layer).timing.cycles);
        }
        let (model, trace, rtl) = (cycles[0], cycles[1], cycles[2]);
        let ok = model == trace && trace == rtl;
        println!("{:>6} {:>12} {:>12} {:>12} {:>6}", n, rtl, trace, model, ok);
        if !ok {
            return fail(format!("validation mismatch at {n}: rtl={rtl} trace={trace} model={model}"));
        }
        n *= 2;
    }
    println!("validation OK (cycle-exact across all engine backends, Fig 4)");
    Ok(())
}

fn cmd_workloads() -> CliResult<()> {
    println!("{:<4} {:<14} {:>7} {:>16}", "tag", "name", "layers", "MACs");
    for (tag, name) in workloads::TAGS {
        let t = workloads::builtin(name).unwrap();
        println!("{:<4} {:<14} {:>7} {:>16}", tag, name, t.layers.len(), t.total_macs());
    }
    Ok(())
}

fn cmd_artifacts() -> CliResult<()> {
    let dir = default_artifact_dir();
    let rt = Runtime::new(&dir)?;
    println!("runtime platform: {}", rt.platform());
    println!("artifact dir:     {dir:?}");
    let names = rt.available();
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
    }
    for n in names {
        println!("  {n}");
    }
    Ok(())
}
