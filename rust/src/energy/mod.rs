//! Energy model (Fig 6): access-cost accounting over the simulator's
//! exact MAC / SRAM / DRAM counts.
//!
//! The paper reports energy in mJ but gives no technology constants; we
//! use Eyeriss/TPU-era per-access costs (documented in DESIGN.md §3,
//! overridable by the caller) so that *ratios and trends* — which is all
//! Fig 6 compares — are meaningful:
//!
//! * 8-bit MAC:            0.2 pJ/op
//! * SRAM read/write:      6.0 / 7.0 pJ per byte (≈1 MB scratchpad)
//! * DRAM (LPDDR4-class):  160 pJ per byte
//!
//! As §IV-B cautions, "the cost of logic within the accelerator is
//! assumed to be the same for the three dataflows".

use crate::dataflow::Timing;
use crate::memory::DramTraffic;

/// Per-access energy costs in picojoules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    pub mac_pj: f64,
    pub sram_read_pj_per_byte: f64,
    pub sram_write_pj_per_byte: f64,
    pub dram_pj_per_byte: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self::NODE_28NM
    }
}

impl EnergyModel {
    /// ~28 nm mobile-class estimates (the default).
    pub const NODE_28NM: EnergyModel = EnergyModel {
        mac_pj: 0.2,
        sram_read_pj_per_byte: 6.0,
        sram_write_pj_per_byte: 7.0,
        dram_pj_per_byte: 160.0,
    };

    /// ~45 nm Eyeriss-era estimates (Horowitz ISSCC'14 scaling).
    pub const NODE_45NM: EnergyModel = EnergyModel {
        mac_pj: 0.45,
        sram_read_pj_per_byte: 10.0,
        sram_write_pj_per_byte: 11.5,
        dram_pj_per_byte: 200.0,
    };

    /// ~7 nm datacenter-class estimates.
    pub const NODE_7NM: EnergyModel = EnergyModel {
        mac_pj: 0.05,
        sram_read_pj_per_byte: 2.5,
        sram_write_pj_per_byte: 3.0,
        dram_pj_per_byte: 120.0,
    };

    /// Look up a preset by name ("28nm", "45nm", "7nm").
    pub fn preset(name: &str) -> Option<EnergyModel> {
        match name.trim().to_lowercase().as_str() {
            "28nm" => Some(Self::NODE_28NM),
            "45nm" => Some(Self::NODE_45NM),
            "7nm" => Some(Self::NODE_7NM),
            _ => None,
        }
    }

    /// The preset name this model equals, if any — the inverse of
    /// [`EnergyModel::preset`]. Used by the dse campaign spec (which
    /// names its energy model) and by the serve path to check that a
    /// submitted campaign prices energy the way the server's engine
    /// does (cached reports embed energy numbers).
    pub fn preset_name(&self) -> Option<&'static str> {
        if *self == Self::NODE_28NM {
            Some("28nm")
        } else if *self == Self::NODE_45NM {
            Some("45nm")
        } else if *self == Self::NODE_7NM {
            Some("7nm")
        } else {
            None
        }
    }
}

/// Energy split the way Fig 6 stacks it: compute vs memory transfers.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub compute_mj: f64,
    pub sram_mj: f64,
    pub dram_mj: f64,
}

impl EnergyBreakdown {
    pub fn total_mj(&self) -> f64 {
        self.compute_mj + self.sram_mj + self.dram_mj
    }

    /// Fig 6's "memory transfers" bar (SRAM + DRAM).
    pub fn memory_mj(&self) -> f64 {
        self.sram_mj + self.dram_mj
    }
}

const PJ_TO_MJ: f64 = 1e-9;

impl EnergyModel {
    /// Price one layer run: MAC count from the layer, SRAM accesses from
    /// the dataflow timing, DRAM bytes from the memory model.
    pub fn layer_energy(
        &self,
        macs: u64,
        timing: &Timing,
        dram: &DramTraffic,
        word_bytes: u64,
    ) -> EnergyBreakdown {
        let w = word_bytes as f64;
        let sram_read_bytes =
            (timing.sram_reads_ifmap + timing.sram_reads_filter + timing.sram_reads_ofmap) as f64 * w;
        let sram_write_bytes = timing.sram_writes_ofmap as f64 * w;
        EnergyBreakdown {
            compute_mj: macs as f64 * self.mac_pj * PJ_TO_MJ,
            sram_mj: (sram_read_bytes * self.sram_read_pj_per_byte
                + sram_write_bytes * self.sram_write_pj_per_byte)
                * PJ_TO_MJ,
            dram_mj: dram.total() as f64 * self.dram_pj_per_byte * PJ_TO_MJ,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config;
    use crate::dataflow::Dataflow;
    use crate::memory;

    fn breakdown(df: Dataflow) -> EnergyBreakdown {
        let l = LayerShape::conv("c", 28, 28, 3, 3, 16, 32, 1);
        let cfg = config::paper_default();
        let t = df.timing(&l, cfg.array_h, cfg.array_w);
        let (dram, _) = memory::simulate(df, &l, &cfg);
        EnergyModel::default().layer_energy(l.macs(), &t, &dram, cfg.word_bytes)
    }

    #[test]
    fn all_components_positive() {
        for df in Dataflow::ALL {
            let e = breakdown(df);
            assert!(e.compute_mj > 0.0 && e.sram_mj > 0.0 && e.dram_mj > 0.0, "{df}");
            assert!((e.total_mj() - (e.compute_mj + e.memory_mj())).abs() < 1e-12);
        }
    }

    #[test]
    fn compute_energy_is_dataflow_invariant() {
        // same MACs price the same regardless of mapping (§IV-B caveat)
        let a = breakdown(Dataflow::Os).compute_mj;
        let b = breakdown(Dataflow::Ws).compute_mj;
        let c = breakdown(Dataflow::Is).compute_mj;
        assert!((a - b).abs() < 1e-15 && (b - c).abs() < 1e-15);
    }

    #[test]
    fn hand_computed_small_case() {
        let m = EnergyModel::default();
        let t = Timing {
            cycles: 100,
            row_folds: 1,
            col_folds: 1,
            utilization: 1.0,
            mapping_efficiency: 1.0,
            sram_reads_ifmap: 1000,
            sram_reads_filter: 500,
            sram_writes_ofmap: 100,
            sram_reads_ofmap: 0,
        };
        let d = DramTraffic { ifmap_bytes: 64, filter_bytes: 36, ofmap_bytes: 0 };
        let e = m.layer_energy(10_000, &t, &d, 1);
        assert!((e.compute_mj - 10_000.0 * 0.2 * 1e-9).abs() < 1e-18);
        assert!((e.sram_mj - (1500.0 * 6.0 + 100.0 * 7.0) * 1e-9).abs() < 1e-18);
        assert!((e.dram_mj - 100.0 * 160.0 * 1e-9).abs() < 1e-18);
    }

    #[test]
    fn presets_resolve_and_order_sanely() {
        assert_eq!(EnergyModel::preset("28nm").unwrap(), EnergyModel::NODE_28NM);
        assert_eq!(EnergyModel::preset(" 45NM ").unwrap(), EnergyModel::NODE_45NM);
        assert!(EnergyModel::preset("3nm").is_none());
        // preset_name is the exact inverse of preset
        for name in ["28nm", "45nm", "7nm"] {
            assert_eq!(EnergyModel::preset(name).unwrap().preset_name(), Some(name));
        }
        let custom = EnergyModel { mac_pj: 1.0, ..EnergyModel::NODE_28NM };
        assert_eq!(custom.preset_name(), None);
        // newer nodes must be cheaper per op across the board
        let (n45, n28, n7) = (EnergyModel::NODE_45NM, EnergyModel::NODE_28NM, EnergyModel::NODE_7NM);
        assert!(n45.mac_pj > n28.mac_pj && n28.mac_pj > n7.mac_pj);
        assert!(n45.sram_read_pj_per_byte > n28.sram_read_pj_per_byte);
        assert!(n28.dram_pj_per_byte > n7.dram_pj_per_byte);
    }

    #[test]
    fn word_bytes_scales_sram_energy() {
        let m = EnergyModel::default();
        let t = Timing {
            cycles: 10,
            row_folds: 1,
            col_folds: 1,
            utilization: 1.0,
            mapping_efficiency: 1.0,
            sram_reads_ifmap: 10,
            sram_reads_filter: 0,
            sram_writes_ofmap: 0,
            sram_reads_ofmap: 0,
        };
        let d = DramTraffic::default();
        let e1 = m.layer_energy(0, &t, &d, 1).sram_mj;
        let e2 = m.layer_energy(0, &t, &d, 2).sram_mj;
        assert!((e2 - 2.0 * e1).abs() < 1e-18);
    }
}
