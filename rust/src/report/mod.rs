//! Output writers (§III-F): the "metrics files, which summarize the
//! parsed information from the traces — cycle counts, utilization,
//! bandwidth requirements, total data transfers" — as csv, plus a
//! markdown run summary.

use std::path::Path;

use crate::sim::WorkloadReport;
use crate::util::csv::CsvWriter;
use crate::Result;

/// Per-layer compute report (cycles / utilization / folds).
pub fn compute_report(r: &WorkloadReport) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "layer",
        "cycles",
        "utilization_pct",
        "mapping_efficiency_pct",
        "row_folds",
        "col_folds",
        "macs",
    ]);
    for l in &r.layers {
        w.row(&[
            l.name().to_string(),
            l.timing.cycles.to_string(),
            format!("{:.3}", l.timing.utilization * 100.0),
            format!("{:.3}", l.timing.mapping_efficiency * 100.0),
            l.timing.row_folds.to_string(),
            l.timing.col_folds.to_string(),
            l.layer.macs().to_string(),
        ]);
    }
    w
}

/// Per-layer SRAM traffic report (word accesses).
pub fn sram_report(r: &WorkloadReport) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "layer",
        "ifmap_reads",
        "filter_reads",
        "ofmap_writes",
        "ofmap_partial_reads",
        "total",
    ]);
    for l in &r.layers {
        w.row(&[
            l.name().to_string(),
            l.timing.sram_reads_ifmap.to_string(),
            l.timing.sram_reads_filter.to_string(),
            l.timing.sram_writes_ofmap.to_string(),
            l.timing.sram_reads_ofmap.to_string(),
            l.timing.sram_total().to_string(),
        ]);
    }
    w
}

/// Per-layer DRAM traffic + stall-free bandwidth report.
pub fn dram_report(r: &WorkloadReport) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "layer",
        "dram_ifmap_bytes",
        "dram_filter_bytes",
        "dram_ofmap_bytes",
        "avg_read_bw",
        "peak_read_bw",
        "avg_write_bw",
    ]);
    for l in &r.layers {
        w.row(&[
            l.name().to_string(),
            l.dram.ifmap_bytes.to_string(),
            l.dram.filter_bytes.to_string(),
            l.dram.ofmap_bytes.to_string(),
            format!("{:.4}", l.bandwidth.avg_read_bw),
            format!("{:.4}", l.bandwidth.peak_read_bw),
            format!("{:.4}", l.bandwidth.avg_write_bw),
        ]);
    }
    w
}

/// Per-layer energy report (mJ, Fig 6 split).
pub fn energy_report(r: &WorkloadReport) -> CsvWriter {
    let mut w = CsvWriter::new(&["layer", "compute_mj", "sram_mj", "dram_mj", "total_mj"]);
    for l in &r.layers {
        w.row(&[
            l.name().to_string(),
            format!("{:.6}", l.energy.compute_mj),
            format!("{:.6}", l.energy.sram_mj),
            format!("{:.6}", l.energy.dram_mj),
            format!("{:.6}", l.energy.total_mj()),
        ]);
    }
    w
}

/// Human-readable run summary (markdown).
pub fn summary_markdown(r: &WorkloadReport, total_pes: u64) -> String {
    let e = r.total_energy();
    let d = r.total_dram();
    format!(
        "# SCALE-Sim run: {name}\n\n\
         | metric | value |\n|---|---|\n\
         | layers | {layers} |\n\
         | total MACs | {macs} |\n\
         | total cycles | {cycles} |\n\
         | overall utilization | {util:.2}% |\n\
         | DRAM ifmap/filter/ofmap bytes | {di} / {df} / {do_} |\n\
         | avg DRAM read bandwidth | {bw:.4} bytes/cycle |\n\
         | energy (compute/sram/dram) mJ | {ec:.4} / {es:.4} / {ed:.4} |\n\
         | total energy | {et:.4} mJ |\n",
        name = r.workload,
        layers = r.layers.len(),
        macs = r.total_macs(),
        cycles = r.total_cycles(),
        util = r.overall_utilization(total_pes) * 100.0,
        di = d.ifmap_bytes,
        df = d.filter_bytes,
        do_ = d.ofmap_bytes,
        bw = r.avg_dram_read_bw(),
        ec = e.compute_mj,
        es = e.sram_mj,
        ed = e.dram_mj,
        et = e.total_mj(),
    )
}

/// Human-readable dse campaign summary: coverage, the two Pareto
/// frontiers (runtime-vs-energy, runtime-vs-peak-DRAM-bandwidth), and a
/// per-workload "fastest / lowest-energy design" conclusion — the
/// Fig 7/8-style takeaways, computed over the full frontier instead of
/// one curve at a time. Deterministic: no wall-clock, stable ordering,
/// so two journals holding the same points print byte-identical
/// summaries (the CI kill+resume identity check relies on this).
pub fn dse_summary(out: &crate::dse::CampaignOutcome) -> String {
    use std::fmt::Write as _;

    let c = &out.campaign;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "campaign {:?} [{} energy]: {} workloads x {} dataflows x {} arrays x {} nodes x {} partitions x {} sram x {} bw = {} points ({} completed)",
        c.name,
        c.energy,
        c.workloads.len(),
        c.dataflows.len(),
        c.arrays.len(),
        c.nodes.len(),
        c.partitions.len(),
        c.sram_kb.len(),
        c.dram_bw.len(),
        c.len(),
        out.completed.len(),
    );

    let frontier_table = |s: &mut String, title: &str, front: &[usize], col: &str, y: &dyn Fn(&crate::dse::PointMetrics) -> f64| {
        let _ = writeln!(s, "\nPareto frontier — {title} ({} of {} points):", front.len(), out.completed.len());
        let _ = writeln!(
            s,
            "{:<14} {:>4} {:>9} {:>6} {:>9} {:>8} {:>8} {:>14} {:>14}",
            "workload", "df", "array", "nodes", "partition", "sram_kb", "bw_B/cyc", "total_cycles", col
        );
        for &i in front {
            let cp = &out.completed[i];
            let p = &cp.point;
            let _ = writeln!(
                s,
                "{:<14} {:>4} {:>9} {:>6} {:>9} {:>8} {:>8} {:>14} {:>14.6}",
                p.workload,
                p.dataflow.name(),
                format!("{}x{}", p.array_h, p.array_w),
                p.nodes,
                p.partition.name(),
                p.sram_kb,
                p.dram_bw,
                cp.metrics.total_cycles(),
                y(&cp.metrics),
            );
        }
    };
    frontier_table(
        &mut s,
        "runtime vs energy",
        &out.frontier_runtime_energy,
        "energy_mJ",
        &|m| m.energy_mj,
    );
    frontier_table(
        &mut s,
        "runtime vs peak DRAM bandwidth",
        &out.frontier_runtime_bw,
        "peak_bw_B/cyc",
        &|m| m.peak_dram_bw,
    );

    let _ = writeln!(s, "\nper-workload best designs:");
    for w in &c.workloads {
        let mut fastest: Option<&crate::dse::CompletedPoint> = None;
        let mut thriftiest: Option<&crate::dse::CompletedPoint> = None;
        for cp in out.completed.iter().filter(|cp| &cp.point.workload == w) {
            if fastest.map_or(true, |b| cp.metrics.total_cycles() < b.metrics.total_cycles()) {
                fastest = Some(cp);
            }
            if thriftiest.map_or(true, |b| cp.metrics.energy_mj < b.metrics.energy_mj) {
                thriftiest = Some(cp);
            }
        }
        let (Some(f), Some(t)) = (fastest, thriftiest) else { continue };
        let multi = |p: &crate::dse::CampaignPoint| {
            if p.nodes > 1 {
                format!(" x{} nodes ({})", p.nodes, p.partition.name())
            } else {
                String::new()
            }
        };
        let _ = writeln!(
            s,
            "  {w}: fastest = {} {}x{}{} sram {} bw {} ({} cycles, util {:.1}%); lowest energy = {} {}x{}{} sram {} bw {} ({:.6} mJ)",
            f.point.dataflow.name(),
            f.point.array_h,
            f.point.array_w,
            multi(&f.point),
            f.point.sram_kb,
            f.point.dram_bw,
            f.metrics.total_cycles(),
            f.metrics.utilization * 100.0,
            t.point.dataflow.name(),
            t.point.array_h,
            t.point.array_w,
            multi(&t.point),
            t.point.sram_kb,
            t.point.dram_bw,
            t.metrics.energy_mj,
        );
    }
    s
}

/// Human-readable §IV-E scale-up vs scale-out summary (`scale-sim
/// scaleout`): the Fig 9 runtime-ratio and Fig 10 weight-bandwidth-ratio
/// columns per (workload, PE budget), plus the aggregate interconnect
/// bandwidth the scale-out side demands — the number the paper only
/// tabulates, reported here from the engine's multi-array model.
pub fn scaleout_summary(points: &[crate::engine::multi::ScaleoutPoint]) -> String {
    use std::fmt::Write as _;

    let mut s = String::new();
    let _ = writeln!(
        s,
        "Fig 9/10 — scale-up vs scale-out (8x8 nodes; runtime up/out > 1 => scale-out wins, weight-bw up/out < 1 => scale-up cheaper)"
    );
    let _ = writeln!(
        s,
        "{:<14} {:>9} {:>7} {:>6} {:>14} {:>14} {:>8} {:>8} {:>12} {:>12}",
        "workload",
        "partition",
        "PEs",
        "nodes",
        "up_cycles",
        "out_cycles",
        "up/out",
        "wbw_u/o",
        "icn_avg_B/c",
        "icn_peak_B/c"
    );
    for p in points {
        let c = &p.comparison;
        let _ = writeln!(
            s,
            "{:<14} {:>9} {:>7} {:>6} {:>14} {:>14} {:>8.3} {:>8.3} {:>12.4} {:>12.4}",
            p.workload,
            p.partition.name(),
            c.pe_budget,
            c.nodes,
            c.up_cycles,
            c.out_cycles,
            c.runtime_ratio(),
            c.weight_bw_ratio(),
            p.interconnect_avg_bw,
            p.interconnect_peak_bw,
        );
    }
    s
}

/// Write the full report set into `dir` (created if missing).
pub fn write_all(dir: &Path, r: &WorkloadReport, total_pes: u64) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    compute_report(r).write_to(&dir.join("compute_report.csv"))?;
    sram_report(r).write_to(&dir.join("sram_report.csv"))?;
    dram_report(r).write_to(&dir.join("dram_report.csv"))?;
    energy_report(r).write_to(&dir.join("energy_report.csv"))?;
    std::fs::write(dir.join("summary.md"), summary_markdown(r, total_pes))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config::{self, Topology};
    use crate::engine::Partition;
    use crate::sim::Simulator;
    use crate::util::csv;

    #[test]
    fn dse_summary_is_deterministic_and_lists_frontiers() {
        use crate::dse::{self, Campaign, Exec, RunOpts};
        let campaign = Campaign {
            name: "rep".into(),
            workloads: vec!["ncf".into()],
            dataflows: vec![crate::Dataflow::Os],
            arrays: vec![(16, 16), (32, 32)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            sram_kb: vec![64],
            dram_bw: vec![8.0],
            topologies: vec![crate::engine::FabricKind::Flat],
            link_bw: vec![crate::engine::DEFAULT_LINK_BW],
            energy: "28nm".into(),
        };
        let opts = RunOpts { exec: Exec::Local { threads: 1 }, ..RunOpts::default() };
        let out = dse::run_campaign(campaign, &opts).unwrap();
        let a = dse_summary(&out);
        assert_eq!(a, dse_summary(&out), "summary must be deterministic");
        assert!(a.contains("Pareto frontier — runtime vs energy"), "{a}");
        assert!(a.contains("runtime vs peak DRAM bandwidth"), "{a}");
        assert!(a.contains("per-workload best designs"), "{a}");
        assert!(a.contains("ncf"), "{a}");
    }

    #[test]
    fn scaleout_summary_lists_every_point_with_ratios() {
        use crate::engine::multi::ScaleoutPoint;
        let engine = crate::engine::Engine::new(config::paper_default());
        let layers = vec![LayerShape::conv("a", 32, 32, 3, 3, 32, 64, 1)];
        let mut points = Vec::new();
        for pe in [1024u64, 4096] {
            let comparison = engine.compare_scaling_with(&layers, pe, Partition::Auto);
            let mc = crate::engine::MultiArrayConfig::paper(pe);
            let m = engine.run_multi(
                &Topology::new("a", layers.clone()),
                &crate::engine::MultiArrayConfig { partition: Partition::Auto, ..mc },
            );
            points.push(ScaleoutPoint {
                workload: "a".into(),
                partition: Partition::Auto,
                comparison,
                interconnect_avg_bw: m.avg_interconnect_bw(),
                interconnect_peak_bw: m.peak_interconnect_bw(),
            });
        }
        let s = scaleout_summary(&points);
        assert_eq!(s, scaleout_summary(&points), "deterministic");
        assert!(s.contains("Fig 9"), "{s}");
        assert!(s.contains("1024") && s.contains("4096"), "{s}");
        assert!(s.contains("auto"), "{s}");
        assert_eq!(s.lines().count(), 2 + points.len());
    }

    fn report() -> WorkloadReport {
        let sim = Simulator::new(config::paper_default());
        sim.run_topology(&Topology::new(
            "t",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::fc("fc", 1, 64, 10),
            ],
        ))
    }

    #[test]
    fn compute_report_has_layer_rows() {
        let rows = csv::parse(compute_report(&report()).as_str());
        assert_eq!(rows.len(), 3); // header + 2 layers
        assert_eq!(rows[1][0], "c1");
        assert!(rows[1][1].parse::<u64>().unwrap() > 0);
    }

    #[test]
    fn all_reports_parse_as_csv() {
        let r = report();
        for w in [compute_report(&r), sram_report(&r), dram_report(&r), energy_report(&r)] {
            let rows = csv::parse(w.as_str());
            assert!(rows.len() >= 3);
            let width = rows[0].len();
            assert!(rows.iter().all(|row| row.len() == width));
        }
    }

    #[test]
    fn summary_mentions_workload_and_cycles() {
        let r = report();
        let md = summary_markdown(&r, 128 * 128);
        assert!(md.contains("SCALE-Sim run: t"));
        assert!(md.contains(&r.total_cycles().to_string()));
    }

    #[test]
    fn write_all_creates_files() {
        let dir = std::env::temp_dir().join(format!("scale_sim_report_{}", std::process::id()));
        write_all(&dir, &report(), 128 * 128).unwrap();
        for f in [
            "compute_report.csv",
            "sram_report.csv",
            "dram_report.csv",
            "energy_report.csv",
            "summary.md",
        ] {
            assert!(dir.join(f).exists(), "{f}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
