//! Fold schedules: the sequence of stationary-operand mappings a dataflow
//! performs for one layer (§III-B "the resources are time multiplexed").
//!
//! A [`Fold`] records *when* it runs (start cycle, duration), *how much*
//! of the array it uses, and *which operand ranges* it touches. The
//! iteration order contract is documented in [`crate::trace`]:
//! the accumulation/reuse dimension is innermost.

use crate::arch::LayerShape;
use crate::dataflow::{is, os, ws, Dataflow};
use crate::util::ceil_div;

/// One stationary-operand mapping of the array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fold {
    /// Sequence number (0-based, schedule order).
    pub index: u64,
    /// First cycle of this fold.
    pub start: u64,
    /// Duration in cycles.
    pub cycles: u64,
    /// Rows of the array actually mapped.
    pub r_used: u64,
    /// Columns of the array actually mapped.
    pub c_used: u64,
    /// Half-open range along the row dimension
    /// (OS: output pixels; WS/IS: window elements).
    pub row_range: (u64, u64),
    /// Half-open range along the column dimension
    /// (OS/WS: filters; IS: output pixels).
    pub col_range: (u64, u64),
}

/// Iterator over the fold schedule; O(1) memory, exact start cycles.
pub struct FoldIter {
    df: Dataflow,
    // totals along row/col fold dimensions
    total_r: u64,
    total_c: u64,
    rows: u64,
    cols: u64,
    // the streamed-operand count fixing per-fold duration (OS: K,
    // WS: Npx, IS: Nf)
    stream: u64,
    // outer/inner fold grid: outer==row dim for OS, col dim for WS/IS
    outer_count: u64,
    inner_count: u64,
    outer: u64,
    inner: u64,
    index: u64,
    cycle: u64,
}

/// Build the fold schedule for `layer` under `df` on a `rows x cols` array.
pub fn fold_schedule(df: Dataflow, layer: &LayerShape, rows: u64, cols: u64) -> FoldIter {
    let (npx, k, nf) = layer.gemm_view();
    let (total_r, total_c, stream) = match df {
        Dataflow::Os => (npx, nf, k),
        Dataflow::Ws => (k, nf, npx),
        Dataflow::Is => (k, npx, nf),
    };
    let row_folds = ceil_div(total_r, rows);
    let col_folds = ceil_div(total_c, cols);
    // OS: row-outer (pixels advance slowly, filters cycle);
    // WS/IS: col-outer (stationary cols advance slowly, window folds
    // accumulate innermost).
    let (outer_count, inner_count) = match df {
        Dataflow::Os => (row_folds, col_folds),
        Dataflow::Ws | Dataflow::Is => (col_folds, row_folds),
    };
    FoldIter {
        df,
        total_r,
        total_c,
        rows,
        cols,
        stream,
        outer_count,
        inner_count,
        outer: 0,
        inner: 0,
        index: 0,
        cycle: 0,
    }
}

impl FoldIter {
    fn range(total: u64, tile: u64, idx: u64) -> (u64, u64) {
        let lo = idx * tile;
        (lo, (lo + tile).min(total))
    }
}

impl Iterator for FoldIter {
    type Item = Fold;

    fn next(&mut self) -> Option<Fold> {
        if self.outer >= self.outer_count {
            return None;
        }
        let (row_idx, col_idx) = match self.df {
            Dataflow::Os => (self.outer, self.inner),
            Dataflow::Ws | Dataflow::Is => (self.inner, self.outer),
        };
        let row_range = Self::range(self.total_r, self.rows, row_idx);
        let col_range = Self::range(self.total_c, self.cols, col_idx);
        let r_used = row_range.1 - row_range.0;
        let c_used = col_range.1 - col_range.0;
        let cycles = match self.df {
            Dataflow::Os => os::fold_cycles(r_used, c_used, self.stream),
            Dataflow::Ws => ws::fold_cycles(r_used, c_used, self.stream),
            Dataflow::Is => is::fold_cycles(r_used, c_used, self.stream),
        };
        let fold = Fold {
            index: self.index,
            start: self.cycle,
            cycles,
            r_used,
            c_used,
            row_range,
            col_range,
        };
        self.index += 1;
        self.cycle += cycles;
        self.inner += 1;
        if self.inner == self.inner_count {
            self.inner = 0;
            self.outer += 1;
        }
        Some(fold)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let total = (self.outer_count * self.inner_count - self.index) as usize;
        (total, Some(total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> LayerShape {
        LayerShape::conv("c", 10, 10, 3, 3, 4, 10, 1)
    }

    #[test]
    fn schedule_covers_all_folds_and_cycles() {
        let l = layer();
        for df in Dataflow::ALL {
            let t = df.timing(&l, 8, 8);
            let folds: Vec<Fold> = fold_schedule(df, &l, 8, 8).collect();
            assert_eq!(folds.len() as u64, t.row_folds * t.col_folds, "{df}");
            let total: u64 = folds.iter().map(|f| f.cycles).sum();
            assert_eq!(total, t.cycles, "{df}");
            // starts are contiguous and ordered
            let mut expect = 0;
            for f in &folds {
                assert_eq!(f.start, expect, "{df} fold {}", f.index);
                expect += f.cycles;
            }
        }
    }

    #[test]
    fn ranges_tile_the_operand_dims() {
        let l = layer();
        for df in Dataflow::ALL {
            let (npx, k, nf) = l.gemm_view();
            let (tr, tc) = match df {
                Dataflow::Os => (npx, nf),
                Dataflow::Ws => (k, nf),
                Dataflow::Is => (k, npx),
            };
            let mut covered = 0u64;
            for f in fold_schedule(df, &l, 8, 8) {
                assert!(f.row_range.1 <= tr && f.col_range.1 <= tc);
                assert_eq!(f.r_used, f.row_range.1 - f.row_range.0);
                assert_eq!(f.c_used, f.col_range.1 - f.col_range.0);
                covered += f.r_used * f.c_used;
            }
            assert_eq!(covered, tr * tc, "{df}");
        }
    }

    #[test]
    fn os_is_row_outer() {
        // first col_folds folds share the same row_range under OS
        let l = LayerShape::gemm("mm", 20, 8, 20); // 3x3 folds on 8x8
        let folds: Vec<Fold> = fold_schedule(Dataflow::Os, &l, 8, 8).collect();
        assert_eq!(folds[0].row_range, folds[1].row_range);
        assert_ne!(folds[0].col_range, folds[1].col_range);
    }

    #[test]
    fn ws_is_col_outer() {
        let l = LayerShape::gemm("mm", 20, 20, 20); // K folds inner
        let folds: Vec<Fold> = fold_schedule(Dataflow::Ws, &l, 8, 8).collect();
        assert_eq!(folds[0].col_range, folds[1].col_range);
        assert_ne!(folds[0].row_range, folds[1].row_range);
    }

    #[test]
    fn size_hint_is_exact() {
        let l = layer();
        let it = fold_schedule(Dataflow::Os, &l, 8, 8);
        let (lo, hi) = it.size_hint();
        assert_eq!(Some(lo), hi);
        assert_eq!(lo, it.count());
    }
}
