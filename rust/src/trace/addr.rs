//! Address generation for trace events (Table I's *Offset* parameters).
//!
//! Layouts (word addresses; the config's `word_bytes` scales to bytes at
//! the memory model, not here — traces are word-granular like the
//! original tool's):
//!
//! * IFMAP: row-major `(h, w, c)` from `IfmapOffset`.
//! * Filters: filter-major `(m, dr, ds, c)` from `FilterOffset` — each
//!   filter's `K` words contiguous, element order matching the im2col
//!   window order used by the Python kernel's GEMM view.
//! * OFMAP: row-major `(pixel, channel)` from `OfmapOffset`.

use crate::arch::LayerShape;
use crate::config::ArchConfig;

/// Precomputed geometry for O(1) address computation per event.
#[derive(Clone, Copy, Debug)]
pub struct AddressMap {
    ifmap_offset: u64,
    filter_offset: u64,
    ofmap_offset: u64,
    ifmap_w: u64,
    channels: u64,
    filt_w: u64,
    stride: u64,
    ofmap_w: u64,
    window: u64,
    num_filters: u64,
}

impl AddressMap {
    pub fn new(layer: &LayerShape, cfg: &ArchConfig) -> Self {
        AddressMap {
            ifmap_offset: cfg.ifmap_offset,
            filter_offset: cfg.filter_offset,
            ofmap_offset: cfg.ofmap_offset,
            ifmap_w: layer.ifmap_w,
            channels: layer.channels,
            filt_w: layer.filt_w,
            stride: layer.stride,
            ofmap_w: layer.ofmap_w(),
            window: layer.window(),
            num_filters: layer.num_filters,
        }
    }

    /// IFMAP word feeding output pixel `px`'s window element `e`.
    ///
    /// `e` decomposes as `(dr, ds, ch)` over the `(R, S, C)` window, the
    /// same order the Python `im2col` uses.
    #[inline]
    pub fn ifmap(&self, px: u64, e: u64) -> u64 {
        let oy = px / self.ofmap_w;
        let ox = px % self.ofmap_w;
        let sc = self.filt_w * self.channels;
        let dr = e / sc;
        let rem = e % sc;
        let ds = rem / self.channels;
        let ch = rem % self.channels;
        let y = oy * self.stride + dr;
        let x = ox * self.stride + ds;
        self.ifmap_offset + (y * self.ifmap_w + x) * self.channels + ch
    }

    /// Filter word: filter `f`, window element `e`.
    #[inline]
    pub fn filter(&self, f: u64, e: u64) -> u64 {
        self.filter_offset + f * self.window + e
    }

    /// OFMAP word: output pixel `px`, output channel `f`.
    #[inline]
    pub fn ofmap(&self, px: u64, f: u64) -> u64 {
        self.ofmap_offset + px * self.num_filters + f
    }

    /// Walk IFMAP addresses for window elements `[e0, e1)` of pixel
    /// `px`, invoking `f(k, addr)` where `k = e - e0`.
    ///
    /// Incremental (+1 / +C / +W*C) address stepping — the trace
    /// generator's hot loop; equivalent to calling [`Self::ifmap`] per
    /// element but without the per-element div/mod (≈3x faster whole-
    /// trace generation, EXPERIMENTS.md §Perf iteration 1).
    #[inline]
    pub fn walk_window(&self, px: u64, e0: u64, e1: u64, mut f: impl FnMut(u64, u64)) {
        debug_assert!(e0 <= e1);
        if e0 == e1 {
            return;
        }
        let oy = px / self.ofmap_w;
        let ox = px % self.ofmap_w;
        let origin =
            self.ifmap_offset + (oy * self.stride * self.ifmap_w + ox * self.stride) * self.channels;
        // decompose e0 once
        let sc = self.filt_w * self.channels;
        let dr0 = e0 / sc;
        let rem = e0 % sc;
        let mut ds = rem / self.channels;
        let mut ch = rem % self.channels;
        let row_stride = self.ifmap_w * self.channels;
        let mut addr = origin + dr0 * row_stride + ds * self.channels + ch;
        for k in 0..e1 - e0 {
            f(k, addr);
            // advance (dr, ds, ch) one element; the +1 covers the
            // ch->ds carry, the row jump covers the ds->dr carry
            ch += 1;
            addr += 1;
            if ch == self.channels {
                ch = 0;
                ds += 1;
                if ds == self.filt_w {
                    ds = 0;
                    addr += row_stride - self.filt_w * self.channels;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn amap(layer: &LayerShape) -> AddressMap {
        AddressMap::new(layer, &config::paper_default())
    }

    #[test]
    fn ifmap_unit_filter_is_identity_layout() {
        // 1x1 filter, stride 1: window element == channel, px walks (h,w)
        let l = LayerShape::conv("c", 4, 4, 1, 1, 3, 2, 1);
        let a = amap(&l);
        assert_eq!(a.ifmap(0, 0), 0);
        assert_eq!(a.ifmap(0, 2), 2); // channel 2
        assert_eq!(a.ifmap(1, 0), 3); // next pixel = next (w) position
        assert_eq!(a.ifmap(5, 1), 5 * 3 + 1);
    }

    #[test]
    fn ifmap_window_walks_rows_then_cols_then_channels() {
        let l = LayerShape::conv("c", 5, 5, 3, 3, 2, 1, 1);
        let a = amap(&l);
        // px 0, element (dr=1, ds=2, ch=1) => e = 1*(3*2) + 2*2 + 1 = 11
        // ifmap coord y=1, x=2, ch=1 => (1*5+2)*2+1 = 15
        assert_eq!(a.ifmap(0, 11), 15);
    }

    #[test]
    fn stride_shifts_window_origin() {
        let l = LayerShape::conv("c", 9, 9, 3, 3, 1, 1, 2);
        let a = amap(&l);
        // px 1 is ox=1 -> window origin x = 2
        assert_eq!(a.ifmap(1, 0), 2);
        // px 4 is oy=1 (ofmap_w = 4) -> origin y = 2
        assert_eq!(a.ifmap(4, 0), 2 * 9);
    }

    #[test]
    fn filters_are_contiguous_per_filter() {
        let l = LayerShape::conv("c", 8, 8, 3, 3, 4, 6, 1);
        let a = amap(&l);
        let k = l.window();
        assert_eq!(a.filter(0, 0), 10_000_000);
        assert_eq!(a.filter(2, 5), 10_000_000 + 2 * k + 5);
    }

    #[test]
    fn walk_window_matches_pointwise_ifmap() {
        // exhaustive over every pixel and every sub-range for an odd
        // geometry (stride 2, rectangular filter and ifmap)
        let l = LayerShape::conv("c", 9, 7, 3, 2, 3, 2, 2);
        let a = amap(&l);
        let k = l.window();
        for px in 0..l.npx() {
            for e0 in [0, 1, k / 2, k - 1] {
                let mut got = Vec::new();
                a.walk_window(px, e0, k, |kk, addr| got.push((kk, addr)));
                let want: Vec<(u64, u64)> =
                    (e0..k).map(|e| (e - e0, a.ifmap(px, e))).collect();
                assert_eq!(got, want, "px={px} e0={e0}");
            }
        }
    }

    #[test]
    fn walk_window_empty_range() {
        let l = LayerShape::conv("c", 5, 5, 3, 3, 2, 1, 1);
        let a = amap(&l);
        let mut n = 0;
        a.walk_window(0, 4, 4, |_, _| n += 1);
        assert_eq!(n, 0);
    }

    #[test]
    fn ofmap_channel_minor() {
        let l = LayerShape::conv("c", 8, 8, 3, 3, 4, 6, 1);
        let a = amap(&l);
        assert_eq!(a.ofmap(0, 0), 20_000_000);
        assert_eq!(a.ofmap(3, 2), 20_000_000 + 3 * 6 + 2);
    }
}
