//! Cycle-accurate SRAM address-trace generation (§III-E steps 1–2).
//!
//! SCALE-Sim's inside-out model: assume the PE array never stalls, and
//! emit the cycle-stamped SRAM read addresses that the top and left edges
//! must receive for that to hold, plus the OFMAP write trace. Runtime is
//! the cycle of the last trace event + 1; parsing the traffic yields the
//! utilization and SRAM access counts.
//!
//! Two granularities are exposed:
//!
//! * [`fold_schedule`] — the O(#folds) schedule of stationary-operand
//!   mappings (start cycle, duration, operand ranges). The memory model
//!   ([`crate::memory`]) and the scale-out engine consume this.
//! * [`generate`] — the full per-cycle, per-port address trace (one event
//!   per SRAM word moved), streamed into a caller-supplied sink so that
//!   no trace is ever materialized unless the user dumps csv. Unit tests
//!   assert event counts and the final cycle agree *exactly* with the
//!   closed-form [`crate::dataflow::Timing`].
//!
//! Fold iteration order (documented contract, relied on by `memory`):
//! OS walks output-pixel folds outer / filter folds inner; WS walks
//! filter folds outer / window folds inner; IS walks window-pixel folds
//! outer(cols) / window-element folds inner — i.e. the accumulation
//! dimension is always innermost so partial sums retire as early as
//! possible (§III-C's OFMAP partition holds one fold-group of partials).

mod addr;
pub mod banks;
mod folds;
pub mod writer;

pub use addr::AddressMap;
pub use banks::{bank_analysis, BankReport};
pub use folds::{fold_schedule, Fold, FoldIter};
pub use writer::{port_trace, PortTrace};

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;

/// One SRAM port event class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Access {
    /// Left-edge (OS/WS) or top-edge (IS) ifmap word read.
    IfmapRead,
    /// Top-edge (OS/WS fill) or left-edge (IS stream) filter word read.
    FilterRead,
    /// OFMAP (possibly partial) word write.
    OfmapWrite,
    /// Partial-sum re-read for accumulation across window folds (WS/IS).
    OfmapRead,
}

/// Generate the full cycle-accurate trace for one layer.
///
/// Events are emitted fold-by-fold; within a fold, port-major. The sink
/// receives `(cycle, access, address)`. Addresses follow [`AddressMap`]
/// (operand offsets from the config, row-major layouts).
pub fn generate(
    df: Dataflow,
    layer: &LayerShape,
    cfg: &ArchConfig,
    mut sink: impl FnMut(u64, Access, u64),
) {
    let amap = AddressMap::new(layer, cfg);
    let (npx, k, nf) = layer.gemm_view();
    for fold in fold_schedule(df, layer, cfg.array_h, cfg.array_w) {
        let b = fold.start;
        let (r, c) = (fold.r_used, fold.c_used);
        match df {
            Dataflow::Os => {
                // rows <-> output px [row_range), cols <-> filters [col_range)
                let (p0, _) = fold.row_range;
                let (f0, _) = fold.col_range;
                for i in 0..r {
                    let base = b + i;
                    amap.walk_window(p0 + i, 0, k, |kk, addr| {
                        sink(base + kk, Access::IfmapRead, addr);
                    });
                }
                for j in 0..c {
                    let base = b + j;
                    let a0 = amap.filter(f0 + j, 0);
                    for kk in 0..k {
                        sink(base + kk, Access::FilterRead, a0 + kk);
                    }
                }
                for i in 0..r {
                    for j in 0..c {
                        let cyc = b + j + (k - 1) + (r - 1) + (r - i);
                        sink(cyc, Access::OfmapWrite, amap.ofmap(p0 + i, f0 + j));
                    }
                }
            }
            Dataflow::Ws => {
                // rows <-> window elems [row_range), cols <-> filters
                let (e0, _) = fold.row_range;
                let (f0, _) = fold.col_range;
                // fill: bottom row's weight first
                for t in 0..r {
                    let e = e0 + (r - 1 - t);
                    for j in 0..c {
                        sink(b + t, Access::FilterRead, amap.filter(f0 + j, e));
                    }
                }
                // stream all Npx windows, skewed by row (element-range
                // walk per window avoids per-event div/mod)
                for p in 0..npx {
                    let base = b + r + p;
                    amap.walk_window(p, e0, e0 + r, |i, addr| {
                        sink(base + i, Access::IfmapRead, addr);
                    });
                }
                // outputs exit per (window, column)
                for p in 0..npx {
                    for j in 0..c {
                        let cyc = b + 2 * r + p + j;
                        let a = amap.ofmap(p, f0 + j);
                        if e0 > 0 {
                            sink(cyc, Access::OfmapRead, a);
                        }
                        sink(cyc, Access::OfmapWrite, a);
                    }
                }
            }
            Dataflow::Is => {
                // rows <-> window elems, cols <-> windows (output px)
                let (e0, _) = fold.row_range;
                let (p0, _) = fold.col_range;
                for j in 0..c {
                    // per-window element walk, reversed to bottom-first
                    // fill cycles (cycle = b + (r-1-i))
                    amap.walk_window(p0 + j, e0, e0 + r, |i, addr| {
                        sink(b + (r - 1 - i), Access::IfmapRead, addr);
                    });
                }
                for f in 0..nf {
                    let base = b + r + f;
                    let a0 = amap.filter(f, e0);
                    for i in 0..r {
                        sink(base + i, Access::FilterRead, a0 + i);
                    }
                }
                for f in 0..nf {
                    for j in 0..c {
                        let cyc = b + 2 * r + f + j;
                        let a = amap.ofmap(p0 + j, f);
                        if e0 > 0 {
                            sink(cyc, Access::OfmapRead, a);
                        }
                        sink(cyc, Access::OfmapWrite, a);
                    }
                }
            }
        }
    }
}

/// Trace summary produced by a single streaming pass (§III-E step 2:
/// "parse the generated traffic traces").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    pub last_cycle: u64,
    pub ifmap_reads: u64,
    pub filter_reads: u64,
    pub ofmap_writes: u64,
    pub ofmap_reads: u64,
}

impl TraceSummary {
    /// Runtime in cycles (last event + 1).
    pub fn cycles(&self) -> u64 {
        self.last_cycle + 1
    }
}

/// Run [`generate`] with a counting sink.
pub fn summarize(df: Dataflow, layer: &LayerShape, cfg: &ArchConfig) -> TraceSummary {
    let mut s = TraceSummary::default();
    generate(df, layer, cfg, |cycle, access, _addr| {
        s.last_cycle = s.last_cycle.max(cycle);
        match access {
            Access::IfmapRead => s.ifmap_reads += 1,
            Access::FilterRead => s.filter_reads += 1,
            Access::OfmapWrite => s.ofmap_writes += 1,
            Access::OfmapRead => s.ofmap_reads += 1,
        }
    });
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn small_cfg(rows: u64, cols: u64) -> ArchConfig {
        ArchConfig { array_h: rows, array_w: cols, ..config::paper_default() }
    }

    fn layers() -> Vec<LayerShape> {
        vec![
            LayerShape::gemm("mm8", 8, 8, 8),
            LayerShape::gemm("mm_resid", 9, 10, 11),
            LayerShape::conv("conv", 8, 8, 3, 3, 4, 6, 1),
            LayerShape::conv("strided", 9, 9, 3, 3, 2, 5, 2),
            LayerShape::fc("fc", 1, 40, 12),
        ]
    }

    #[test]
    fn trace_agrees_with_analytical_for_all_dataflows() {
        for layer in layers() {
            for df in Dataflow::ALL {
                for &(r, c) in &[(8u64, 8u64), (4, 8), (8, 4), (16, 3)] {
                    let cfg = small_cfg(r, c);
                    let t = df.timing(&layer, r, c);
                    let s = summarize(df, &layer, &cfg);
                    assert_eq!(s.cycles(), t.cycles, "{df} {} {r}x{c} cycles", layer.name);
                    assert_eq!(s.ifmap_reads, t.sram_reads_ifmap, "{df} {} ifmap", layer.name);
                    assert_eq!(s.filter_reads, t.sram_reads_filter, "{df} {} filter", layer.name);
                    assert_eq!(s.ofmap_writes, t.sram_writes_ofmap, "{df} {} ofwrites", layer.name);
                    assert_eq!(s.ofmap_reads, t.sram_reads_ofmap, "{df} {} ofreads", layer.name);
                }
            }
        }
    }

    #[test]
    fn addresses_stay_in_operand_regions() {
        let layer = LayerShape::conv("conv", 8, 8, 3, 3, 4, 6, 1);
        let cfg = small_cfg(8, 8);
        generate(Dataflow::Os, &layer, &cfg, |_cyc, access, addr| match access {
            Access::IfmapRead => {
                assert!(addr >= cfg.ifmap_offset);
                assert!(addr < cfg.ifmap_offset + layer.ifmap_elems());
            }
            Access::FilterRead => {
                assert!(addr >= cfg.filter_offset);
                assert!(addr < cfg.filter_offset + layer.filter_elems());
            }
            Access::OfmapWrite | Access::OfmapRead => {
                assert!(addr >= cfg.ofmap_offset);
                assert!(addr < cfg.ofmap_offset + layer.ofmap_elems());
            }
        });
    }

    #[test]
    fn ofmap_written_exactly_once_per_element_os() {
        let layer = LayerShape::conv("conv", 6, 6, 3, 3, 2, 4, 1);
        let cfg = small_cfg(8, 8);
        let mut seen = std::collections::HashMap::new();
        generate(Dataflow::Os, &layer, &cfg, |_c, a, addr| {
            if a == Access::OfmapWrite {
                *seen.entry(addr).or_insert(0u32) += 1;
            }
        });
        assert_eq!(seen.len() as u64, layer.ofmap_elems());
        assert!(seen.values().all(|&n| n == 1));
    }

    #[test]
    fn ws_partial_sums_rewrite_same_addresses() {
        // K folds: every ofmap address written row_folds times under WS
        let layer = LayerShape::gemm("mm", 4, 20, 4); // K=20 on 8 rows -> 3 folds
        let cfg = small_cfg(8, 8);
        let mut writes = std::collections::HashMap::new();
        generate(Dataflow::Ws, &layer, &cfg, |_c, a, addr| {
            if a == Access::OfmapWrite {
                *writes.entry(addr).or_insert(0u32) += 1;
            }
        });
        assert!(writes.values().all(|&n| n == 3), "{writes:?}");
    }

    #[test]
    fn events_fit_within_runtime() {
        for df in Dataflow::ALL {
            let layer = LayerShape::conv("c", 7, 7, 3, 3, 3, 5, 1);
            let cfg = small_cfg(4, 4);
            let cycles = df.timing(&layer, 4, 4).cycles;
            generate(df, &layer, &cfg, |cyc, _, _| {
                assert!(cyc < cycles, "{df}: event at {cyc} >= runtime {cycles}");
            });
        }
    }
}
