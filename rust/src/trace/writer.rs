//! Original-format trace writers (§III-F: "The traces are also csv
//! files, which list the cycle and the addresses of data transferred in
//! the given cycle").
//!
//! SCALE-Sim's classic output format puts one row per cycle with one
//! column per array edge port:
//!
//! ```text
//! cycle, if<0>, if<1>, ..., if<rows-1>, filt<0>, ..., filt<cols-1>
//! 42, 1024, 1052, , , 10000000, 10000147, ,
//! ```
//!
//! Blank cells mean the port is idle that cycle (skew fill/drain). The
//! OFMAP write trace is one row per cycle with `cols` columns.

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;

use super::{generate, Access};

/// Per-cycle port matrix for one layer, bounded to `max_cycles` rows.
pub struct PortTrace {
    pub rows: usize,
    pub cols: usize,
    /// cycle -> ifmap port slots (rows wide).
    pub ifmap: Vec<Vec<Option<u64>>>,
    /// cycle -> filter port slots (cols wide).
    pub filter: Vec<Vec<Option<u64>>>,
    /// cycle -> ofmap write slots (cols wide).
    pub ofmap: Vec<Vec<Option<u64>>>,
    pub truncated: bool,
}

/// Assemble the port-matrix view of the SRAM trace (bounded).
pub fn port_trace(
    df: Dataflow,
    layer: &LayerShape,
    cfg: &ArchConfig,
    max_cycles: usize,
) -> PortTrace {
    let rows = cfg.array_h as usize;
    let cols = cfg.array_w as usize;
    let runtime = df.timing(layer, cfg.array_h, cfg.array_w).cycles as usize;
    let n = runtime.min(max_cycles);
    let mut t = PortTrace {
        rows,
        cols,
        ifmap: vec![vec![None; rows]; n],
        filter: vec![vec![None; cols]; n],
        ofmap: vec![vec![None; cols]; n],
        truncated: runtime > max_cycles,
    };
    generate(df, layer, cfg, |cycle, access, addr| {
        let c = cycle as usize;
        if c >= n {
            return;
        }
        // place in the first free slot of the port group — ports fire in
        // generation order, which is row/col-major within a fold
        let slots = match access {
            Access::IfmapRead => &mut t.ifmap[c],
            Access::FilterRead => &mut t.filter[c],
            Access::OfmapWrite => &mut t.ofmap[c],
            Access::OfmapRead => return, // RMW partner of the write
        };
        if let Some(slot) = slots.iter_mut().find(|s| s.is_none()) {
            *slot = Some(addr);
        }
    });
    t
}

fn render(rows: &[Vec<Option<u64>>], width: usize) -> String {
    let mut out = String::new();
    for (cycle, slots) in rows.iter().enumerate() {
        out.push_str(&cycle.to_string());
        for j in 0..width {
            out.push_str(", ");
            if let Some(a) = slots[j] {
                out.push_str(&a.to_string());
            }
        }
        out.push('\n');
    }
    out
}

impl PortTrace {
    /// The classic `sram_read.csv` body (cycle, ifmap ports, filter ports).
    pub fn sram_read_csv(&self) -> String {
        let mut out = String::from("cycle");
        for i in 0..self.rows {
            out.push_str(&format!(", if<{i}>"));
        }
        for j in 0..self.cols {
            out.push_str(&format!(", filt<{j}>"));
        }
        out.push('\n');
        for (cycle, (ifr, fr)) in self.ifmap.iter().zip(&self.filter).enumerate() {
            out.push_str(&cycle.to_string());
            for s in ifr.iter().chain(fr.iter()) {
                out.push_str(", ");
                if let Some(a) = s {
                    out.push_str(&a.to_string());
                }
            }
            out.push('\n');
        }
        out
    }

    /// The classic `sram_write.csv` body (cycle, ofmap ports).
    pub fn sram_write_csv(&self) -> String {
        let mut out = String::from("cycle");
        for j in 0..self.cols {
            out.push_str(&format!(", of<{j}>"));
        }
        out.push('\n');
        out.push_str(&render(&self.ofmap, self.cols));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn cfg() -> ArchConfig {
        ArchConfig { array_h: 4, array_w: 4, ..config::paper_default() }
    }

    fn layer() -> LayerShape {
        LayerShape::gemm("mm", 4, 6, 4)
    }

    #[test]
    fn port_counts_match_summary() {
        for df in Dataflow::ALL {
            let t = port_trace(df, &layer(), &cfg(), 100_000);
            assert!(!t.truncated);
            let s = super::super::summarize(df, &layer(), &cfg());
            let ifr: usize = t.ifmap.iter().flatten().filter(|s| s.is_some()).count();
            let fr: usize = t.filter.iter().flatten().filter(|s| s.is_some()).count();
            let ow: usize = t.ofmap.iter().flatten().filter(|s| s.is_some()).count();
            assert_eq!(ifr as u64, s.ifmap_reads, "{df}");
            assert_eq!(fr as u64, s.filter_reads, "{df}");
            assert_eq!(ow as u64, s.ofmap_writes, "{df}");
        }
    }

    #[test]
    fn csv_has_one_row_per_cycle() {
        let t = port_trace(Dataflow::Os, &layer(), &cfg(), 100_000);
        let csv = t.sram_read_csv();
        let runtime = Dataflow::Os.timing(&layer(), 4, 4).cycles as usize;
        assert_eq!(csv.lines().count(), runtime + 1); // header + cycles
        // header lists every port
        assert!(csv.starts_with("cycle, if<0>, if<1>, if<2>, if<3>, filt<0>"));
    }

    #[test]
    fn truncation_flag_set() {
        let t = port_trace(Dataflow::Os, &layer(), &cfg(), 5);
        assert!(t.truncated);
        assert_eq!(t.ifmap.len(), 5);
    }

    #[test]
    fn write_trace_contains_all_outputs() {
        let t = port_trace(Dataflow::Os, &layer(), &cfg(), 100_000);
        let csv = t.sram_write_csv();
        // 16 output addresses must appear
        let l = layer();
        let count = csv.matches("200000").count(); // ofmap offset prefix
        assert_eq!(count as u64, l.ofmap_elems());
    }

    #[test]
    fn ports_never_oversubscribed() {
        // every cycle fits within the physical port counts (no dropped
        // events): total placed == total generated, checked above; here
        // ensure no row needed more slots than exist
        let t = port_trace(Dataflow::Ws, &layer(), &cfg(), 100_000);
        for row in t.ifmap.iter().chain(&t.filter).chain(&t.ofmap) {
            assert!(row.len() <= 4 + 4);
        }
    }
}
