//! SRAM bank requirement analysis (§IV-B: "for square arrays, WS and IS
//! use half the amount of SRAM banks as compared to OS. SRAM banks are
//! expensive resources in terms of area footprint.")
//!
//! A single-ported SRAM bank can serve one word per cycle; the number of
//! banks each partition needs for stall-free operation is the *maximum
//! number of simultaneous accesses in any cycle* of the trace. This
//! module parses the generated trace and reports exactly that.

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;

use super::{generate, Access};

/// Peak per-cycle port pressure for each SRAM partition.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BankReport {
    pub ifmap_banks: u64,
    pub filter_banks: u64,
    pub ofmap_banks: u64,
    /// Peak *simultaneous* operand (ifmap+filter) accesses in any single
    /// cycle — the bank count a shared operand SRAM would need. This is
    /// where WS/IS halve OS's cost: their fill and stream phases never
    /// overlap, while OS reads both edges at full width every cycle.
    pub operand_banks: u64,
}

impl BankReport {
    /// Total single-ported banks for stall-free operation with a shared
    /// operand SRAM plus the OFMAP partition.
    pub fn total(&self) -> u64 {
        self.operand_banks + self.ofmap_banks
    }
}

/// Compute the bank requirement by streaming the cycle-accurate trace.
///
/// Memory cost is O(runtime) counters; the trace itself is never stored.
pub fn bank_analysis(df: Dataflow, layer: &LayerShape, cfg: &ArchConfig) -> BankReport {
    let cycles = df.timing(layer, cfg.array_h, cfg.array_w).cycles as usize;
    let mut ifmap = vec![0u32; cycles];
    let mut filter = vec![0u32; cycles];
    let mut ofmap = vec![0u32; cycles];
    generate(df, layer, cfg, |cycle, access, _addr| {
        let c = cycle as usize;
        match access {
            Access::IfmapRead => ifmap[c] += 1,
            Access::FilterRead => filter[c] += 1,
            Access::OfmapWrite | Access::OfmapRead => ofmap[c] += 1,
        }
    });
    BankReport {
        ifmap_banks: ifmap.iter().copied().max().unwrap_or(0) as u64,
        filter_banks: filter.iter().copied().max().unwrap_or(0) as u64,
        ofmap_banks: ofmap.iter().copied().max().unwrap_or(0) as u64,
        operand_banks: ifmap
            .iter()
            .zip(&filter)
            .map(|(a, b)| a + b)
            .max()
            .unwrap_or(0) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn cfg(n: u64) -> ArchConfig {
        ArchConfig { array_h: n, array_w: n, ..config::paper_default() }
    }

    fn layer() -> LayerShape {
        LayerShape::conv("c", 14, 14, 3, 3, 16, 32, 1)
    }

    #[test]
    fn os_needs_row_plus_col_operand_banks() {
        // OS streams r ifmap + c filter words every steady-state cycle
        let n = 8;
        let b = bank_analysis(Dataflow::Os, &layer(), &cfg(n));
        assert_eq!(b.ifmap_banks, n);
        assert_eq!(b.filter_banks, n);
    }

    #[test]
    fn ws_and_is_need_half_the_operand_banks_of_os() {
        // §IV-B's claim, verified from the traces: WS/IS never read both
        // operand SRAMs at full width in the same cycle (fill and stream
        // phases are disjoint), so peak *simultaneous* operand pressure
        // is half of OS's on a square array.
        let n = 8;
        let os = bank_analysis(Dataflow::Os, &layer(), &cfg(n));
        assert_eq!(os.operand_banks, 2 * n);
        for df in [Dataflow::Ws, Dataflow::Is] {
            let b = bank_analysis(df, &layer(), &cfg(n));
            assert_eq!(b.operand_banks, os.operand_banks / 2, "{df}");
        }
    }

    #[test]
    fn residual_folds_do_not_exceed_array_dims() {
        let l = LayerShape::conv("odd", 9, 9, 3, 3, 3, 5, 1);
        for df in Dataflow::ALL {
            let b = bank_analysis(df, &l, &cfg(16));
            assert!(b.ifmap_banks <= 16 && b.filter_banks <= 16, "{df}: {b:?}");
        }
    }

    #[test]
    fn ofmap_pressure_bounded_by_columns() {
        for df in Dataflow::ALL {
            let b = bank_analysis(df, &layer(), &cfg(8));
            // one output (possibly plus one partial re-read) per column
            // port per cycle
            assert!(b.ofmap_banks <= 2 * 8, "{df}: {}", b.ofmap_banks);
            assert!(b.ofmap_banks >= 1);
        }
    }
}
