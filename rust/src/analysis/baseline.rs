//! The ratcheted lint baseline: accepted findings, checked in, counts
//! only allowed to go *down*.
//!
//! Format (`lint.baseline` at the lint root) — `#` comments, one
//! `RULE path count` line per (rule, file) with accepted findings:
//!
//! ```text
//! # pre-pr-violations: 41
//! R2 rust/src/dse/journal.rs 1
//! R4 rust/src/dse/exec.rs 6
//! ```
//!
//! Counts are keyed per (rule, file) rather than per line number, so
//! unrelated edits that shift lines never invalidate the baseline —
//! only *adding* or *removing* a violation does. The check is a
//! two-sided ratchet:
//!
//! * more findings than baselined → **new violations**, fail with the
//!   `file:line` of every finding in the group;
//! * fewer findings than baselined → **stale entry**, fail too: a fix
//!   must shrink the checked-in file, so the count monotonically
//!   decreases and nobody can silently re-spend a fixed allowance.
//!
//! The optional `# pre-pr-violations: N` header records what the
//! linter counted on the tree *before* the pass landed; the baseline
//! total must stay strictly below it (the gate proves it ratchets).

use std::collections::BTreeMap;

use super::rules::{Finding, RuleId};

/// Parsed baseline: per-(rule, file) accepted counts plus the ratchet
/// floor header.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Baseline {
    /// `# pre-pr-violations: N` header, if present: the finding count
    /// of the tree before this pass existed. The baseline total must
    /// stay strictly below it.
    pub pre_pr_violations: Option<u64>,
    /// (rule, root-relative path) → accepted finding count (> 0).
    pub counts: BTreeMap<(RuleId, String), u64>,
}

/// One way the current findings disagree with the baseline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Drift {
    /// More findings than baselined: `lines` locates every finding in
    /// the group (the newcomers are among them).
    New { rule: RuleId, file: String, have: u64, allowed: u64, lines: Vec<u32> },
    /// Fewer findings than baselined: the fix must also shrink the
    /// baseline file.
    Stale { rule: RuleId, file: String, have: u64, allowed: u64 },
}

impl Baseline {
    /// Parse the baseline text. Errors (returned, never panicked) on
    /// unknown rules, malformed lines, or duplicate (rule, file) keys.
    pub fn parse(text: &str) -> std::result::Result<Baseline, String> {
        let mut b = Baseline::default();
        for (n, raw) in text.lines().enumerate() {
            let lineno = n + 1;
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(comment) = line.strip_prefix('#') {
                if let Some(v) = comment.trim().strip_prefix("pre-pr-violations:") {
                    let parsed = v
                        .trim()
                        .parse::<u64>()
                        .map_err(|_| format!("line {lineno}: bad pre-pr-violations count"))?;
                    b.pre_pr_violations = Some(parsed);
                }
                continue;
            }
            let mut parts = line.split_whitespace();
            let (rule, file, count) = match (parts.next(), parts.next(), parts.next(), parts.next())
            {
                (Some(r), Some(f), Some(c), None) => (r, f, c),
                _ => {
                    return Err(format!(
                        "line {lineno}: expected `RULE path count`, got {line:?}"
                    ))
                }
            };
            let rule = RuleId::parse(rule)
                .ok_or_else(|| format!("line {lineno}: unknown rule {rule:?}"))?;
            let count = count
                .parse::<u64>()
                .map_err(|_| format!("line {lineno}: bad count {count:?}"))?;
            if count == 0 {
                return Err(format!(
                    "line {lineno}: zero-count entry — remove the line instead"
                ));
            }
            if b.counts.insert((rule, file.to_string()), count).is_some() {
                return Err(format!("line {lineno}: duplicate entry {rule:?} {file}", rule = rule.code()));
            }
        }
        b.validate()?;
        Ok(b)
    }

    /// The self-consistency invariant: with a recorded pre-PR count,
    /// the baseline total must sit strictly below it.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if let Some(floor) = self.pre_pr_violations {
            if self.total() >= floor {
                return Err(format!(
                    "ratchet regressed: baseline holds {} findings but the pre-PR tree \
                     produced {floor} — the baseline must only shrink",
                    self.total()
                ));
            }
        }
        Ok(())
    }

    /// Build a baseline accepting exactly `findings` (no ratchet header).
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut counts: BTreeMap<(RuleId, String), u64> = BTreeMap::new();
        for f in findings {
            *counts.entry((f.rule, f.file.clone())).or_insert(0) += 1;
        }
        Baseline { pre_pr_violations: None, counts }
    }

    /// Serialize back to the checked-in format (stable: BTreeMap order).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "# scale-sim lint baseline — accepted findings, one `RULE path count` line per\n\
             # (rule, file). New findings fail `scale-sim lint`; fixing a finding requires\n\
             # removing it here, so counts only ratchet down. Regenerate (after deliberate\n\
             # review!) with `scale-sim lint --write-baseline`.\n",
        );
        if let Some(floor) = self.pre_pr_violations {
            out.push_str(&format!("# pre-pr-violations: {floor}\n"));
        }
        for ((rule, file), count) in &self.counts {
            out.push_str(&format!("{} {} {}\n", rule.code(), file, count));
        }
        out
    }

    /// Total accepted findings.
    pub fn total(&self) -> u64 {
        self.counts.values().sum()
    }

    /// Compare current findings against the baseline. Empty result =
    /// the gate passes.
    pub fn check(&self, findings: &[Finding]) -> Vec<Drift> {
        let mut have: BTreeMap<(RuleId, String), Vec<u32>> = BTreeMap::new();
        for f in findings {
            have.entry((f.rule, f.file.clone())).or_default().push(f.line);
        }
        let mut drift = Vec::new();
        for ((rule, file), lines) in &have {
            let allowed = self.counts.get(&(*rule, file.clone())).copied().unwrap_or(0);
            if lines.len() as u64 > allowed {
                drift.push(Drift::New {
                    rule: *rule,
                    file: file.clone(),
                    have: lines.len() as u64,
                    allowed,
                    lines: lines.clone(),
                });
            } else if (lines.len() as u64) < allowed {
                drift.push(Drift::Stale {
                    rule: *rule,
                    file: file.clone(),
                    have: lines.len() as u64,
                    allowed,
                });
            }
        }
        for ((rule, file), &allowed) in &self.counts {
            if !have.contains_key(&(*rule, file.clone())) {
                drift.push(Drift::Stale { rule: *rule, file: file.clone(), have: 0, allowed });
            }
        }
        drift.sort_by(|a, b| a.key().cmp(&b.key()));
        drift
    }
}

impl Drift {
    fn key(&self) -> (String, RuleId, u8) {
        match self {
            Drift::New { rule, file, .. } => (file.clone(), *rule, 0),
            Drift::Stale { rule, file, .. } => (file.clone(), *rule, 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(rule: RuleId, file: &str, line: u32) -> Finding {
        Finding { rule, file: file.into(), line, message: "m".into() }
    }

    #[test]
    fn round_trips_through_render_and_parse() {
        let findings = vec![
            f(RuleId::R4, "rust/src/a.rs", 3),
            f(RuleId::R4, "rust/src/a.rs", 9),
            f(RuleId::R2, "rust/src/b.rs", 1),
        ];
        let mut b = Baseline::from_findings(&findings);
        b.pre_pr_violations = Some(40);
        let back = Baseline::parse(&b.render()).unwrap();
        assert_eq!(back, b);
        assert_eq!(back.total(), 3);
        assert!(back.check(&findings).is_empty(), "exact match = clean gate");
    }

    #[test]
    fn new_findings_and_stale_entries_both_fail() {
        let b = Baseline::parse("R4 rust/src/a.rs 1\nR2 rust/src/b.rs 1\n").unwrap();
        // one extra R4 in a.rs, and b.rs fixed but not removed from baseline
        let now = vec![f(RuleId::R4, "rust/src/a.rs", 3), f(RuleId::R4, "rust/src/a.rs", 7)];
        let drift = b.check(&now);
        assert_eq!(drift.len(), 2);
        assert!(matches!(&drift[0], Drift::New { file, have: 2, allowed: 1, lines, .. }
            if file == "rust/src/a.rs" && lines == &vec![3, 7]));
        assert!(matches!(&drift[1], Drift::Stale { file, have: 0, allowed: 1, .. }
            if file == "rust/src/b.rs"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Baseline::parse("R9 x 1\n").is_err(), "unknown rule");
        assert!(Baseline::parse("R1 x\n").is_err(), "missing count");
        assert!(Baseline::parse("R1 x 0\n").is_err(), "zero count");
        assert!(Baseline::parse("R1 x 1\nR1 x 2\n").is_err(), "duplicate");
        assert!(Baseline::parse("# pre-pr-violations: nope\n").is_err());
    }

    #[test]
    fn ratchet_floor_is_enforced() {
        assert!(Baseline::parse("# pre-pr-violations: 2\nR1 x 1\n").is_ok());
        let err = Baseline::parse("# pre-pr-violations: 1\nR1 x 1\n").unwrap_err();
        assert!(err.contains("ratchet"), "{err}");
    }
}
