//! Lightweight item parser for the interprocedural lint rules.
//!
//! Built directly over [`super::lexer`]'s token stream — still no
//! syntax tree, no syn. It recovers just enough structure for the
//! call graph: `fn` declarations (name, visibility, receiver, body
//! token range) with their enclosing `mod`/`impl` scopes, and each
//! file's `use` alias map with `crate`/`self`/`super` heads resolved
//! against the file's module path. Everything else (expressions,
//! generics, types) is skipped with balanced-bracket matching.
//!
//! The parser is deliberately conservative: any construct it cannot
//! follow it drops. A dropped item costs call edges — *missed*
//! findings downstream — never invented ones.

use std::collections::BTreeMap;

use super::lexer::{Tok, Token};

/// The crate's lib name as it appears in integration-test `use` paths.
const CRATE_NAME: &str = "scale_sim";

/// One `fn` item.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FnItem {
    pub name: String,
    /// Enclosing `impl` type name (`None` for free functions).
    pub qual: Option<String>,
    /// Module path within the crate, `::`-joined (`"dse::journal"`;
    /// the crate root is `""`).
    pub module: String,
    /// 1-based line of the `fn` name.
    pub line: u32,
    /// Token index of the `fn` name (for test-region lookups).
    pub decl_tok: usize,
    /// Plain `pub` only — `pub(crate)`/`pub(super)` are not public
    /// surface and are deliberately `false` here.
    pub is_pub: bool,
    /// Whether the first parameter is a `self` receiver.
    pub has_receiver: bool,
    /// Token range of the body *including both braces*; `None` for
    /// bodyless trait method declarations.
    pub body: Option<(usize, usize)>,
}

impl FnItem {
    /// Crate-rooted `::`-joined path (`"dse::journal::Journal::append"`).
    pub fn path(&self) -> String {
        let mut segs: Vec<&str> = Vec::new();
        if !self.module.is_empty() {
            segs.extend(self.module.split("::"));
        }
        if let Some(q) = &self.qual {
            segs.push(q);
        }
        segs.push(&self.name);
        segs.join("::")
    }
}

/// Items recovered from one file.
#[derive(Clone, Debug, Default)]
pub struct FileItems {
    /// The file's own module path segments (empty at the crate root).
    pub module: Vec<String>,
    pub fns: Vec<FnItem>,
    /// `use` alias map: local name -> crate-rooted path (`"Campaign"`
    /// -> `"dse::Campaign"`). External paths (`std`, ...) keep their
    /// head segment and simply never match a crate item.
    pub uses: BTreeMap<String, String>,
    /// Resolved prefixes of glob imports (`use x::*;`).
    pub globs: Vec<String>,
}

/// Module path a root-relative file implements: `rust/src/dse/journal.rs`
/// -> `["dse", "journal"]`; `mod.rs` maps to its directory; `lib.rs`,
/// `main.rs`, tests and benches map to the crate root (empty).
pub fn module_path(rel: &str) -> Vec<String> {
    let Some(stripped) = rel.strip_prefix("rust/src/") else {
        return Vec::new(); // tests/benches address the crate externally
    };
    let mut segs: Vec<String> = stripped.split('/').map(str::to_string).collect();
    let Some(last) = segs.pop() else {
        return Vec::new();
    };
    match last.as_str() {
        "lib.rs" | "main.rs" | "mod.rs" => {}
        other => segs.push(other.strip_suffix(".rs").unwrap_or(other).to_string()),
    }
    segs
}

/// Resolve a `use`-path's head against the file's module path:
/// `crate::`/`scale_sim::` roots it, `self::` prepends the module,
/// each `super::` pops one segment. Anything else (std, core, ...) is
/// left as written.
pub fn resolve_path(segs: &[String], base: &[String]) -> String {
    let mut rest: &[String] = segs;
    let mut root: Vec<String> = Vec::new();
    let head = rest.first().map(String::as_str);
    if head == Some("crate") || head == Some(CRATE_NAME) {
        rest = &rest[1..];
    } else if head == Some("self") {
        root = base.to_vec();
        rest = &rest[1..];
    } else if head == Some("super") {
        root = base.to_vec();
        while rest.first().map(String::as_str) == Some("super") {
            root.pop();
            rest = &rest[1..];
        }
    }
    let mut out = root;
    out.extend(rest.iter().cloned());
    out.join("::")
}

pub(crate) fn ident_at<'t>(toks: &'t [Token], i: usize) -> Option<&'t str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

/// Skip a balanced `( .. )` group starting at `open` (on the `(`);
/// returns the index just past the matching `)`.
pub(crate) fn skip_paren_group(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut j = open;
    while let Some(t) = toks.get(j) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

/// Skip a balanced `{ .. }` group starting at `open` (on the `{`);
/// returns the index just past the matching `}`.
pub(crate) fn skip_brace_group(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut j = open;
    while let Some(t) = toks.get(j) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

/// Skip a balanced `< .. >` generics group starting at `open` (on the
/// `<`); returns the index just past the matching `>`. An `->` inside
/// (`Fn() -> T` bounds) does not close the group.
pub(crate) fn skip_angle_group(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut j = open;
    while let Some(t) = toks.get(j) {
        if t.is_punct('-') && toks.get(j + 1).is_some_and(|u| u.is_punct('>')) {
            j += 2;
            continue;
        }
        if t.is_punct('<') {
            depth += 1;
        } else if t.is_punct('>') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

enum ScopeKind {
    Mod(String),
    Impl(Option<String>),
}

struct Scope {
    kind: ScopeKind,
    /// Brace depth of the scope's body tokens; the scope pops when the
    /// walker's depth drops back below it.
    body_depth: i32,
}

/// Parse one file's items from its token stream.
pub fn parse_file(rel: &str, toks: &[Token]) -> FileItems {
    let base = module_path(rel);
    let mut out = FileItems { module: base.clone(), ..FileItems::default() };
    let mut scopes: Vec<Scope> = Vec::new();
    let mut depth = 0i32;
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct('{') {
            depth += 1;
            i += 1;
            continue;
        }
        if t.is_punct('}') {
            depth -= 1;
            while scopes.last().is_some_and(|s| s.body_depth > depth) {
                scopes.pop();
            }
            i += 1;
            continue;
        }
        let Tok::Ident(word) = &t.tok else {
            i += 1;
            continue;
        };
        match word.as_str() {
            "mod" => {
                if ident_at(toks, i + 1).is_some()
                    && toks.get(i + 2).is_some_and(|t| t.is_punct('{'))
                {
                    let name = ident_at(toks, i + 1).unwrap_or("").to_string();
                    scopes.push(Scope { kind: ScopeKind::Mod(name), body_depth: depth + 1 });
                    i += 2; // lands on `{`, handled by the next iteration
                } else {
                    i += 1; // `mod x;` file declaration
                }
            }
            "impl" if at_item_position(toks, i) => match parse_impl_header(toks, i + 1) {
                Some((qual, brace)) => {
                    scopes.push(Scope { kind: ScopeKind::Impl(qual), body_depth: depth + 1 });
                    i = brace; // on `{`
                }
                None => i += 1,
            },
            "fn" => {
                if let Some(item) = parse_fn(toks, i, &base, &scopes) {
                    out.fns.push(item);
                }
                // continue just past the name: nested fns inside the
                // body are discovered by the same walk
                i += 2;
            }
            "use" => {
                i = parse_use(toks, i + 1, &base, &mut out);
            }
            _ => i += 1,
        }
    }
    out
}

/// Whether the token at `i` starts an item (vs `impl Trait` in a type
/// position, which follows `->`, `(`, `:`, `<`, `,`, `=`, or `+`).
fn at_item_position(toks: &[Token], i: usize) -> bool {
    if i == 0 {
        return true;
    }
    match &toks[i - 1].tok {
        Tok::Punct(c) => matches!(c, ';' | '}' | '{' | ']'),
        Tok::Ident(w) => w == "unsafe" || w == "pub",
        Tok::Str(_) => false,
    }
}

/// Parse an `impl` header from just past the keyword: returns the
/// subject type's last path segment and the index of the body's `{`.
fn parse_impl_header(toks: &[Token], mut j: usize) -> Option<(Option<String>, usize)> {
    if toks.get(j).is_some_and(|t| t.is_punct('<')) {
        j = skip_angle_group(toks, j)?;
    }
    let (mut qual, mut k) = parse_type_path(toks, j)?;
    // `impl Trait for Type` — the type after `for` is the subject
    if toks.get(k).is_some_and(|t| t.is_ident("for")) {
        let (q2, k2) = parse_type_path(toks, k + 1)?;
        qual = q2;
        k = k2;
    }
    // find the body `{` past any where clause (no braces occur before it)
    let mut b = k;
    while let Some(t) = toks.get(b) {
        if t.is_punct('{') {
            return Some((qual, b));
        }
        if t.is_punct(';') {
            return None;
        }
        b += 1;
    }
    None
}

/// Parse a type path (`a::b::Type<..>`), returning its last segment
/// and the index just past it. Tuple types yield `None` for the name.
fn parse_type_path(toks: &[Token], mut j: usize) -> Option<(Option<String>, usize)> {
    while toks
        .get(j)
        .is_some_and(|t| t.is_punct('&') || t.is_ident("dyn") || t.is_ident("mut"))
    {
        j += 1;
    }
    if toks.get(j).is_some_and(|t| t.is_punct('(')) {
        return Some((None, skip_paren_group(toks, j)?));
    }
    let mut last: Option<String> = None;
    loop {
        let seg = ident_at(toks, j)?;
        last = Some(seg.to_string());
        j += 1;
        if toks.get(j).is_some_and(|t| t.is_punct('<')) {
            j = skip_angle_group(toks, j)?;
        }
        if toks.get(j).is_some_and(|t| t.is_punct(':'))
            && toks.get(j + 1).is_some_and(|t| t.is_punct(':'))
        {
            j += 2;
            continue;
        }
        return Some((last, j));
    }
}

fn parse_fn(toks: &[Token], i: usize, base: &[String], scopes: &[Scope]) -> Option<FnItem> {
    let name = ident_at(toks, i + 1)?.to_string();
    let decl_tok = i + 1;
    let line = toks.get(decl_tok)?.line;
    // visibility: scan back over fn qualifiers to an optional `pub`
    let mut is_pub = false;
    let mut k = i;
    while k > 0 {
        k -= 1;
        match &toks[k].tok {
            Tok::Ident(w) if matches!(w.as_str(), "const" | "unsafe" | "async" | "extern") => {}
            Tok::Str(_) => {} // the ABI string of `extern "C"`
            Tok::Ident(w) if w == "pub" => {
                is_pub = true;
                break;
            }
            // a `)` here is `pub(crate)`/`pub(super)` — restricted
            // visibility, not public surface
            _ => break,
        }
    }
    // parameter list (generics first, if any)
    let mut j = i + 2;
    if toks.get(j).is_some_and(|t| t.is_punct('<')) {
        j = skip_angle_group(toks, j)?;
    }
    if !toks.get(j).is_some_and(|t| t.is_punct('(')) {
        return None;
    }
    let has_receiver = receiver_at(toks, j + 1);
    let after_params = skip_paren_group(toks, j)?;
    // body: `;` (bodyless trait decl) or `{ .. }`. Brackets are tracked
    // so the `;` inside `-> [u8; N]` does not end the signature.
    let mut b = after_params;
    let mut brackets = 0i32;
    let body = loop {
        let t = toks.get(b)?;
        if t.is_punct('[') {
            brackets += 1;
        } else if t.is_punct(']') {
            brackets -= 1;
        } else if t.is_punct('(') {
            b = skip_paren_group(toks, b)?;
            continue;
        } else if brackets == 0 && t.is_punct(';') {
            break None;
        } else if brackets == 0 && t.is_punct('{') {
            break Some((b, skip_brace_group(toks, b)?));
        }
        b += 1;
    };
    let mut module = base.to_vec();
    let mut qual = None;
    for s in scopes {
        match &s.kind {
            ScopeKind::Mod(m) => module.push(m.clone()),
            ScopeKind::Impl(q) => qual = q.clone(),
        }
    }
    Some(FnItem {
        name,
        qual,
        module: module.join("::"),
        line,
        decl_tok,
        is_pub,
        has_receiver,
        body,
    })
}

/// Whether the parameter list starting at `j` (just past `(`) begins
/// with a `self` receiver, skipping `&`, lifetimes, and `mut`.
fn receiver_at(toks: &[Token], mut j: usize) -> bool {
    for _ in 0..6 {
        let Some(t) = toks.get(j) else { return false };
        if t.is_punct('&') {
            j += 1;
            continue;
        }
        let Some(w) = ident_at(toks, j) else { return false };
        if w == "self" {
            return true;
        }
        // `mut self`, or a lifetime name before `mut`/`self` (the lexer
        // emits lifetime names as plain idents)
        let next_is_recv = ident_at(toks, j + 1).is_some_and(|n| n == "self" || n == "mut");
        if w == "mut" || next_is_recv {
            j += 1;
            continue;
        }
        return false;
    }
    false
}

/// Parse one `use` declaration from just past the keyword; returns the
/// index past the terminating `;`.
fn parse_use(toks: &[Token], mut i: usize, base: &[String], out: &mut FileItems) -> usize {
    // leading `::` of an explicitly-external path
    if toks.get(i).is_some_and(|t| t.is_punct(':'))
        && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
    {
        i += 2;
    }
    let mut j = use_tree(toks, i, &[], base, out);
    while let Some(t) = toks.get(j) {
        if t.is_punct(';') {
            return j + 1;
        }
        // never run away past a malformed tree into item territory
        if t.is_punct('{') || t.is_punct('}') {
            return j;
        }
        j += 1;
    }
    j
}

/// Recursive descent over one `use`-tree node; returns the index past it.
fn use_tree(
    toks: &[Token],
    mut i: usize,
    prefix: &[String],
    base: &[String],
    out: &mut FileItems,
) -> usize {
    let mut segs: Vec<String> = prefix.to_vec();
    loop {
        if toks.get(i).is_some_and(|t| t.is_punct('*')) {
            out.globs.push(resolve_path(&segs, base));
            return i + 1;
        }
        if toks.get(i).is_some_and(|t| t.is_punct('{')) {
            i += 1;
            loop {
                i = use_tree(toks, i, &segs, base, out);
                if toks.get(i).is_some_and(|t| t.is_punct(',')) {
                    i += 1;
                    continue;
                }
                if toks.get(i).is_some_and(|t| t.is_punct('}')) {
                    return i + 1;
                }
                return i; // malformed: bail without consuming further
            }
        }
        let Some(seg) = ident_at(toks, i) else { return i };
        let seg = seg.to_string();
        segs.push(seg.clone());
        i += 1;
        if toks.get(i).is_some_and(|t| t.is_punct(':'))
            && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
        {
            i += 2;
            continue;
        }
        // path ends here: optional `as` rename
        let mut alias = seg.clone();
        if toks.get(i).is_some_and(|t| t.is_ident("as")) {
            if let Some(re) = ident_at(toks, i + 1) {
                alias = re.to_string();
                i += 2;
            }
        }
        // `use x::{self, y}`: `self` imports the parent module name
        if seg == "self" {
            segs.pop();
            if alias == "self" {
                match segs.last() {
                    Some(parent) => alias = parent.clone(),
                    None => return i,
                }
            }
        }
        out.uses.insert(alias, resolve_path(&segs, base));
        return i;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn parse(rel: &str, src: &str) -> FileItems {
        parse_file(rel, &lex(src))
    }

    #[test]
    fn module_paths_follow_file_layout() {
        assert_eq!(module_path("rust/src/lib.rs"), Vec::<String>::new());
        assert_eq!(module_path("rust/src/dse/journal.rs"), vec!["dse", "journal"]);
        assert_eq!(module_path("rust/src/analysis/mod.rs"), vec!["analysis"]);
        assert_eq!(module_path("rust/tests/lint.rs"), Vec::<String>::new());
    }

    #[test]
    fn fns_record_visibility_receiver_and_qual() {
        let src = "\
pub struct S;\n\
impl S {\n\
    pub fn new() -> S { S }\n\
    pub(crate) fn helper(&self) {}\n\
    fn private(&mut self, x: u32) -> u32 { x }\n\
}\n\
pub fn free() {}\n\
fn hidden<'a>(s: &'a str) -> &'a str { s }\n";
        let items = parse("rust/src/util/s.rs", src);
        let by_name = |n: &str| items.fns.iter().find(|f| f.name == n);
        let new = by_name("new").expect("new parsed");
        assert!(new.is_pub && !new.has_receiver);
        assert_eq!(new.qual.as_deref(), Some("S"));
        assert_eq!(new.path(), "util::s::S::new");
        let helper = by_name("helper").expect("helper parsed");
        assert!(!helper.is_pub, "pub(crate) is not public surface");
        assert!(helper.has_receiver);
        let private = by_name("private").expect("private parsed");
        assert!(private.has_receiver, "&mut self is a receiver");
        let free = by_name("free").expect("free parsed");
        assert!(free.is_pub && free.qual.is_none());
        let hidden = by_name("hidden").expect("hidden parsed");
        assert!(!hidden.has_receiver, "lifetime-generic fn, plain arg");
    }

    #[test]
    fn trait_impls_attribute_methods_to_the_subject_type() {
        let src = "\
impl std::fmt::Display for Wide<u8> {\n\
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result { Ok(()) }\n\
}\n\
fn after() {}\n";
        let items = parse("rust/src/util/w.rs", src);
        let fmt = items.fns.iter().find(|f| f.name == "fmt").expect("fmt parsed");
        assert_eq!(fmt.qual.as_deref(), Some("Wide"));
        let after = items.fns.iter().find(|f| f.name == "after").expect("after parsed");
        assert_eq!(after.qual, None, "impl scope popped at its closing brace");
    }

    #[test]
    fn use_maps_resolve_crate_self_super_and_renames() {
        let src = "\
use crate::dse::{Campaign, journal::Journal as J};\n\
use super::backend;\n\
use self::helpers::*;\n\
use std::collections::BTreeMap;\n\
use scale_sim::engine::Engine;\n";
        let items = parse("rust/src/engine/cache.rs", src);
        assert_eq!(items.uses.get("Campaign").map(String::as_str), Some("dse::Campaign"));
        assert_eq!(items.uses.get("J").map(String::as_str), Some("dse::journal::Journal"));
        assert_eq!(items.uses.get("backend").map(String::as_str), Some("engine::backend"));
        assert_eq!(items.globs, vec!["engine::cache::helpers".to_string()]);
        assert_eq!(
            items.uses.get("BTreeMap").map(String::as_str),
            Some("std::collections::BTreeMap"),
            "external paths keep their head"
        );
        assert_eq!(items.uses.get("Engine").map(String::as_str), Some("engine::Engine"));
    }

    #[test]
    fn bodyless_trait_decls_and_nested_fns() {
        let src = "\
pub trait Backend {\n\
    fn simulate(&self, x: u32) -> u32;\n\
    fn tag(&self) -> [u8; 4] { *b\"none\" }\n\
}\n\
fn outer() {\n\
    fn inner() {}\n\
    inner();\n\
}\n";
        let items = parse("rust/src/engine/b.rs", src);
        let sim = items.fns.iter().find(|f| f.name == "simulate").expect("decl parsed");
        assert_eq!(sim.body, None, "bodyless decl");
        let tag = items.fns.iter().find(|f| f.name == "tag").expect("tag parsed");
        assert!(tag.body.is_some(), "the `;` in [u8; 4] does not end the default body");
        assert!(items.fns.iter().any(|f| f.name == "inner"), "nested fn discovered");
    }
}
