//! A minimal Rust lexer for the in-tree static-analysis pass
//! ([`crate::analysis`]) — identifiers, punctuation, and string
//! literals with line numbers, everything else (comments, char
//! literals, lifetimes, numbers, whitespace) consumed and discarded.
//!
//! This is deliberately not a full Rust lexer: the rule visitors only
//! need to see identifier/punct streams that are *guaranteed free of
//! comment and string-literal text* (so `// HashMap` in a doc comment
//! never trips the determinism rule), plus string contents for the one
//! rule that inspects literals (golden-bless hygiene). Handled
//! correctly: line comments, nested block comments, cooked strings
//! with escapes, raw strings (`r#".."#`, any hash depth), byte
//! strings, char literals vs. lifetimes, raw identifiers (`r#type`).

/// One significant token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (raw identifiers are unprefixed).
    Ident(String),
    /// One ASCII punctuation character (`.`, `:`, `(`, `{`, `!`, ...).
    Punct(char),
    /// A string literal's body (escapes left as written — the only
    /// consumer substring-searches, it never unescapes).
    Str(String),
}

/// A token plus the 1-based source line it starts on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

impl Token {
    pub fn is_ident(&self, name: &str) -> bool {
        matches!(&self.tok, Tok::Ident(s) if s == name)
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(&self.tok, Tok::Punct(p) if *p == c)
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src` into significant tokens. Never panics: malformed input
/// (unterminated strings/comments) simply ends the token stream at the
/// point of confusion — the linter runs over sources that rustc has
/// already accepted, so recovery heuristics are not worth their bugs.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Token>,
}

impl<'a> Lexer<'a> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    /// Consume one byte, tracking line numbers.
    fn bump(&mut self) -> Option<u8> {
        let c = self.b.get(self.i).copied();
        if let Some(c) = c {
            self.i += 1;
            if c == b'\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, tok: Tok, line: u32) {
        self.out.push(Token { tok, line });
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(c) = self.peek(0) {
            match c {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.cooked_string(),
                b'\'' => self.char_or_lifetime(),
                _ if is_ident_start(c) => self.ident_or_prefixed_literal(),
                _ if c.is_ascii_digit() => self.number(),
                _ => {
                    let line = self.line;
                    self.bump();
                    if c.is_ascii() {
                        self.push(Tok::Punct(c as char), line);
                    }
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        while let Some(c) = self.bump() {
            if c == b'\n' {
                break;
            }
        }
    }

    fn block_comment(&mut self) {
        self.bump(); // '/'
        self.bump(); // '*'
        let mut depth = 1u32;
        while depth > 0 {
            match self.bump() {
                Some(b'/') if self.peek(0) == Some(b'*') => {
                    self.bump();
                    depth += 1;
                }
                Some(b'*') if self.peek(0) == Some(b'/') => {
                    self.bump();
                    depth -= 1;
                }
                Some(_) => {}
                None => return,
            }
        }
    }

    /// `"..."` with `\"` / `\\` escapes; emits the body.
    fn cooked_string(&mut self) {
        let line = self.line;
        self.bump(); // opening quote
        let mut body = String::new();
        while let Some(c) = self.bump() {
            match c {
                b'"' => break,
                b'\\' => {
                    body.push('\\');
                    if let Some(e) = self.bump() {
                        if e.is_ascii() {
                            body.push(e as char);
                        }
                    }
                }
                _ if c.is_ascii() => body.push(c as char),
                _ => {}
            }
        }
        self.push(Tok::Str(body), line);
    }

    /// `r"..."` / `r#"..."#` (any hash depth); emits the body.
    /// Called with `self.i` on the first `#` or `"` after the prefix.
    fn raw_string(&mut self) {
        let line = self.line;
        let mut hashes = 0usize;
        while self.peek(0) == Some(b'#') {
            self.bump();
            hashes += 1;
        }
        if self.peek(0) != Some(b'"') {
            return; // not actually a raw string (e.g. `r#ident` handled upstream)
        }
        self.bump();
        let mut body = String::new();
        'scan: while let Some(c) = self.bump() {
            if c == b'"' {
                // a close quote counts only when followed by `hashes` hashes
                for k in 0..hashes {
                    if self.peek(k) != Some(b'#') {
                        body.push('"');
                        continue 'scan;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            if c.is_ascii() {
                body.push(c as char);
            }
        }
        self.push(Tok::Str(body), line);
    }

    /// Char literal (`'a'`, `'\n'`) vs lifetime (`'a`, `'static`).
    fn char_or_lifetime(&mut self) {
        self.bump(); // the quote
        match self.peek(0) {
            Some(b'\\') => {
                // escaped char literal: consume escape then closing quote
                self.bump();
                self.bump();
                if self.peek(0) == Some(b'\'') {
                    self.bump();
                }
            }
            Some(c) if is_ident_start(c) => {
                // could be 'a' (char) or 'a / 'static (lifetime)
                let mut k = 0usize;
                while self.peek(k).is_some_and(is_ident_continue) {
                    k += 1;
                }
                if self.peek(k) == Some(b'\'') {
                    // char literal: skip body + closing quote
                    for _ in 0..=k {
                        self.bump();
                    }
                } else {
                    // lifetime: skip the name, no closing quote
                    for _ in 0..k {
                        self.bump();
                    }
                }
            }
            Some(_) => {
                // char literal of a non-ident char, e.g. '(' or ' '
                self.bump();
                if self.peek(0) == Some(b'\'') {
                    self.bump();
                }
            }
            None => {}
        }
    }

    /// Identifier, or the `r"`/`br"`/`b"`/`b'` literal prefixes.
    fn ident_or_prefixed_literal(&mut self) {
        let line = self.line;
        let start = self.i;
        while self.peek(0).is_some_and(is_ident_continue) {
            self.bump();
        }
        let ident = &self.b[start..self.i];
        match (ident, self.peek(0)) {
            (b"r", Some(b'"')) | (b"br", Some(b'"')) | (b"b", Some(b'"')) => {
                self.raw_or_cooked_after_prefix(ident == b"b")
            }
            (b"r", Some(b'#')) | (b"br", Some(b'#')) => {
                // raw string r#".."# — or a raw identifier r#name
                let mut k = 0usize;
                while self.peek(k) == Some(b'#') {
                    k += 1;
                }
                if self.peek(k) == Some(b'"') {
                    self.raw_string();
                } else {
                    // raw identifier: skip the hash, lex the name
                    self.bump();
                    let s = self.i;
                    while self.peek(0).is_some_and(is_ident_continue) {
                        self.bump();
                    }
                    let name = String::from_utf8_lossy(&self.b[s..self.i]).into_owned();
                    self.push(Tok::Ident(name), line);
                }
            }
            (b"b", Some(b'\'')) => {
                self.char_or_lifetime();
            }
            _ => {
                let name = String::from_utf8_lossy(ident).into_owned();
                self.push(Tok::Ident(name), line);
            }
        }
    }

    fn raw_or_cooked_after_prefix(&mut self, cooked: bool) {
        if cooked {
            self.cooked_string();
        } else {
            self.raw_string();
        }
    }

    /// Numeric literal: consumed, not emitted. Stops before `..` so
    /// ranges survive (`0..n`), but eats `1.5`, `1e-3`, `0xff`, `1_000`.
    fn number(&mut self) {
        while self.peek(0).is_some_and(is_ident_continue) {
            self.bump();
        }
        if self.peek(0) == Some(b'.') && self.peek(1).is_some_and(|c| c.is_ascii_digit()) {
            self.bump();
            while self.peek(0).is_some_and(is_ident_continue) {
                self.bump();
            }
        }
        // float exponent with a sign: `1e-3` lexes as ident-continue up
        // to `e`, then needs the sign + digits consumed
        if self.peek(0).is_some_and(|c| c == b'+' || c == b'-')
            && self
                .b
                .get(self.i.wrapping_sub(1))
                .is_some_and(|&p| p == b'e' || p == b'E')
        {
            self.bump();
            while self.peek(0).is_some_and(is_ident_continue) {
                self.bump();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_never_leak_identifiers() {
        let src = r##"
            // HashMap in a line comment
            /* HashMap /* nested */ still comment */
            let x = "HashMap in a string";
            let y = r#"HashMap raw "quoted" body"#;
            let z = b"bytes";
            real_ident();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        assert!(!ids.contains(&"HashMap".to_string()));
        // but the string bodies are preserved for literal-inspecting rules
        let strs: Vec<String> = lex(src)
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Str(s) => Some(s),
                _ => None,
            })
            .collect();
        assert!(strs.iter().any(|s| s.contains("HashMap in a string")));
        assert!(strs.iter().any(|s| s.contains("raw \"quoted\" body")));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let s = ' '; let e = '\\n'; g(c, s, e); }";
        let ids = idents(src);
        assert!(ids.contains(&"a".to_string()), "lifetime name is an ident");
        assert!(!ids.contains(&"x'".to_string()));
        assert!(ids.contains(&"g".to_string()), "lexer must survive past the literals");
    }

    #[test]
    fn line_numbers_are_one_based_and_accurate() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<(String, u32)> = toks
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some((s, t.line)),
                _ => None,
            })
            .collect();
        assert_eq!(
            lines,
            vec![("a".into(), 1), ("b".into(), 2), ("c".into(), 4)]
        );
    }

    #[test]
    fn numbers_do_not_swallow_range_dots() {
        let toks = lex("for i in 0..10 { x[i] = 1.5e-3; }");
        let dots = toks.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(dots, 2, "the `..` survives; the float's dot does not");
    }

    #[test]
    fn raw_identifiers_lex_as_plain_names() {
        assert_eq!(idents("let r#type = 1;"), vec!["let", "type"]);
    }
}
