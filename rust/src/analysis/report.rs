//! Diagnostic rendering for `scale-sim lint` — stable, grep-able,
//! clickable `file:line:` text output.

use super::baseline::Drift;
use super::rules::Finding;

/// Render every finding, one diagnostic per line.
pub fn render_findings(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.render());
        out.push('\n');
    }
    out
}

/// Render baseline drift: new violations with their locations, stale
/// entries with the edit the ratchet demands.
pub fn render_drift(drift: &[Drift], findings: &[Finding]) -> String {
    let mut out = String::new();
    for d in drift {
        match d {
            Drift::New { rule, file, have, allowed, lines } => {
                out.push_str(&format!(
                    "{file}: {have} {code}[{slug}] finding(s), baseline allows {allowed}:\n",
                    code = rule.code(),
                    slug = rule.slug(),
                ));
                for f in findings.iter().filter(|f| f.rule == *rule && &f.file == file) {
                    out.push_str(&format!("  {}\n", f.render()));
                }
                // lines is redundant with the filter above but keeps the
                // drift value self-contained for programmatic consumers
                let _ = lines;
            }
            Drift::Stale { rule, file, have, allowed } => {
                out.push_str(&format!(
                    "{file}: stale baseline entry `{code} {file} {allowed}` — only {have} \
                     finding(s) remain; shrink or remove the line (the ratchet only \
                     goes down)\n",
                    code = rule.code(),
                ));
            }
        }
    }
    out
}

/// One-line pass summary.
pub fn summary(files: usize, findings: usize, baselined: u64) -> String {
    format!(
        "lint: {files} files scanned, {findings} finding(s), {baselined} baselined — clean"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::RuleId;

    #[test]
    fn diagnostics_are_clickable_file_line() {
        let f = Finding {
            rule: RuleId::R4,
            file: "rust/src/a.rs".into(),
            line: 17,
            message: "bad".into(),
        };
        let text = render_findings(&[f]);
        assert_eq!(text, "rust/src/a.rs:17: R4[panic-hygiene]: bad\n");
    }

    #[test]
    fn drift_rendering_names_the_edit() {
        let drift = vec![Drift::Stale {
            rule: RuleId::R2,
            file: "rust/src/b.rs".into(),
            have: 0,
            allowed: 1,
        }];
        let text = render_drift(&drift, &[]);
        assert!(text.contains("stale baseline entry `R2 rust/src/b.rs 1`"), "{text}");
    }
}
