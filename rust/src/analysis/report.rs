//! Diagnostic rendering for `scale-sim lint` — stable, grep-able,
//! clickable `file:line:` text output, plus the `--format json`
//! machine encoding (byte-deterministic: same sources, same bytes).

use super::baseline::Drift;
use super::rules::{Finding, RuleId};
use crate::util::json::Json;

/// Render every finding, one diagnostic per line.
pub fn render_findings(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.render());
        out.push('\n');
    }
    out
}

/// Render baseline drift: new violations with their locations, stale
/// entries with the edit the ratchet demands.
pub fn render_drift(drift: &[Drift], findings: &[Finding]) -> String {
    let mut out = String::new();
    for d in drift {
        match d {
            Drift::New { rule, file, have, allowed, lines } => {
                out.push_str(&format!(
                    "{file}: {have} {code}[{slug}] finding(s), baseline allows {allowed}:\n",
                    code = rule.code(),
                    slug = rule.slug(),
                ));
                for f in findings.iter().filter(|f| f.rule == *rule && &f.file == file) {
                    out.push_str(&format!("  {}\n", f.render()));
                }
                // lines is redundant with the filter above but keeps the
                // drift value self-contained for programmatic consumers
                let _ = lines;
            }
            Drift::Stale { rule, file, have, allowed } => {
                out.push_str(&format!(
                    "{file}: stale baseline entry `{code} {file} {allowed}` — only {have} \
                     finding(s) remain; shrink or remove the line (the ratchet only \
                     goes down)\n",
                    code = rule.code(),
                ));
            }
        }
    }
    out
}

/// Encode findings as one JSON document (trailing newline included):
/// `{"findings":[{"rule":"R2","slug":"lock-discipline","file":..,
/// "line":N,"message":..},..]}`. Key order is fixed and element order
/// follows the (already sorted) findings slice, so the output is
/// byte-identical across runs and machines — safe to diff in CI.
pub fn findings_to_json(findings: &[Finding]) -> String {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("rule", Json::str(f.rule.code())),
                ("slug", Json::str(f.rule.slug())),
                ("file", Json::str(f.file.as_str())),
                ("line", Json::u64(u64::from(f.line))),
                ("message", Json::str(f.message.as_str())),
            ])
        })
        .collect();
    let mut out = Json::obj(vec![("findings", Json::Arr(items))]).to_string();
    out.push('\n');
    out
}

/// Decode [`findings_to_json`] output — the round-trip is pinned by
/// tests so downstream tooling can rely on the schema.
pub fn findings_from_json(text: &str) -> Result<Vec<Finding>, String> {
    let doc = Json::parse(text)?;
    let arr = doc
        .get("findings")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing `findings` array".to_string())?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let rule = item
            .str_field("rule")
            .and_then(RuleId::parse)
            .ok_or_else(|| "missing or unknown `rule` code".to_string())?;
        let file = item
            .str_field("file")
            .ok_or_else(|| "missing `file`".to_string())?
            .to_string();
        let line = item
            .u64_field("line")
            .and_then(|l| u32::try_from(l).ok())
            .ok_or_else(|| "missing `line`".to_string())?;
        let message = item
            .str_field("message")
            .ok_or_else(|| "missing `message`".to_string())?
            .to_string();
        out.push(Finding { rule, file, line, message });
    }
    Ok(out)
}

/// One-line pass summary.
pub fn summary(files: usize, findings: usize, baselined: u64) -> String {
    format!(
        "lint: {files} files scanned, {findings} finding(s), {baselined} baselined — clean"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::RuleId;

    #[test]
    fn diagnostics_are_clickable_file_line() {
        let f = Finding {
            rule: RuleId::R4,
            file: "rust/src/a.rs".into(),
            line: 17,
            message: "bad".into(),
        };
        let text = render_findings(&[f]);
        assert_eq!(text, "rust/src/a.rs:17: R4[panic-hygiene]: bad\n");
    }

    #[test]
    fn json_encoding_round_trips_byte_exactly() {
        let findings = vec![
            Finding {
                rule: RuleId::R6,
                file: "rust/src/a.rs".into(),
                line: 3,
                message: "guard `g` held across call to `b::locks`".into(),
            },
            Finding {
                rule: RuleId::R7,
                file: "rust/src/b.rs".into(),
                line: 9,
                message: "mixes \"cycle\" and wall-time values".into(),
            },
        ];
        let text = findings_to_json(&findings);
        assert!(text.ends_with('\n'));
        let back = findings_from_json(&text).unwrap();
        assert_eq!(back, findings);
        assert_eq!(findings_to_json(&back), text, "encode is a fixpoint");
    }

    #[test]
    fn json_decoding_rejects_malformed_documents() {
        assert!(findings_from_json("{}").is_err());
        assert!(findings_from_json("{\"findings\":[{\"rule\":\"R99\"}]}").is_err());
        assert!(findings_from_json("not json").is_err());
        let empty = findings_from_json("{\"findings\":[]}").unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn drift_rendering_names_the_edit() {
        let drift = vec![Drift::Stale {
            rule: RuleId::R2,
            file: "rust/src/b.rs".into(),
            have: 0,
            allowed: 1,
        }];
        let text = render_drift(&drift, &[]);
        assert!(text.contains("stale baseline entry `R2 rust/src/b.rs 1`"), "{text}");
    }
}
