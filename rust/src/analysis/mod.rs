//! In-tree static analysis: the `scale-sim lint` pass.
//!
//! Everything this reproduction promises rests on bit-exact
//! reproducibility — golden fixtures, dse journal fingerprints, the
//! "deprecated shims stay bit-identical" contract. This module is the
//! machine-checked enforcer of the invariants those promises rest on,
//! run over the repo's **own sources** as a hard CI gate (`ci.sh`):
//!
//! * [`lexer`] — a minimal hand-rolled Rust lexer (std-only, no
//!   syn/clippy: the offline build bans external crates), producing
//!   identifier/punct/string tokens with line numbers and guaranteed
//!   free of comment text.
//! * [`rules`] — the per-file rule visitors (R1 determinism, R2 lock
//!   discipline, R3 shim boundary, R4 panic hygiene, R5 golden-bless
//!   hygiene) with their exemption matrix, plus the interprocedural
//!   families that run over the whole crate at once: R6 lock-order /
//!   transitive lock discipline, R7 two-timeline unit taint, R8
//!   reachability / dead-surface drift.
//! * [`items`] — a lightweight item parser over the lexer: fn / impl /
//!   mod boundaries, `use` alias maps, receiver detection.
//! * [`callgraph`] — crate-wide call-edge resolution (free calls,
//!   qualified paths, method-name heuristics) with explicit confidence.
//! * [`taint`] — cycle- / wall- / byte-class classification of
//!   identifiers for R7.
//! * [`baseline`] — the checked-in ratchet (`lint.baseline`): existing
//!   violations are enumerated, new ones fail CI, fixed ones must be
//!   removed, so the count monotonically decreases.
//! * [`report`] — clickable `file:line:` diagnostic rendering and the
//!   `--format json` encoding.
//!
//! The pass lints itself: this module is `rust/src/` library code and
//! therefore subject to every rule it implements — which is why it
//! contains no `unwrap`/`expect`/`panic!` and no `HashMap`.

pub mod baseline;
pub mod callgraph;
pub mod items;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod taint;

use std::path::{Path, PathBuf};

pub use baseline::{Baseline, Drift};
pub use rules::{classify, lint_source, FileClass, Finding, RuleId};

use crate::{Error, Result};

/// Directories scanned under the lint root.
const LINT_ROOTS: [&str; 3] = ["rust/src", "rust/tests", "rust/benches"];

/// Path components excluded from the scan: the fixture corpus *is*
/// seeded violations (each one asserted by `rust/tests/lint.rs`).
const EXCLUDED_COMPONENTS: [&str; 1] = ["lint_fixtures"];

/// Every `.rs` file the pass covers, as root-relative forward-slash
/// paths, sorted (deterministic walk order regardless of readdir).
pub fn collect_sources(root: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for sub in LINT_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, root, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        // A non-UTF-8 file name can never be part of the corpus (every
        // checked-in source has an ASCII name) and could not be rendered
        // in a finding path anyway — skip it outright rather than
        // letting it bypass the excluded-component check as "".
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if EXCLUDED_COMPONENTS.contains(&name) {
            continue;
        }
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(relative(root, &path));
        }
    }
    Ok(())
}

/// Root-relative path with forward slashes (the form findings, the
/// baseline, and diagnostics all use).
fn relative(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let s = rel.to_string_lossy();
    if s.contains('\\') {
        s.replace('\\', "/")
    } else {
        s.into_owned()
    }
}

/// Lint every source under `root`. Findings are sorted by
/// (file, line, rule) — byte-stable across runs and machines.
pub fn lint_root(root: &Path) -> Result<Vec<Finding>> {
    let files = collect_sources(root)?;
    if files.is_empty() {
        return Err(Error::Config(format!(
            "lint root {} contains no rust/src sources — pass --root at the repo root",
            root.display()
        )));
    }
    let mut sources = Vec::with_capacity(files.len());
    for rel in files {
        let text = std::fs::read_to_string(root.join(&rel))?;
        sources.push((rel, text));
    }
    Ok(lint_crate(&sources))
}

/// Lint an in-memory crate: per-file rules (R1–R5) plus the
/// interprocedural families (R6–R8) that need every file at once.
/// `sources` holds `(root-relative path, text)` pairs; findings come
/// back sorted by (file, line, rule).
pub fn lint_crate(sources: &[(String, String)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (rel, text) in sources {
        out.extend(lint_source(rel, text));
    }
    out.extend(rules::lint_interprocedural(sources));
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

/// Number of files [`lint_root`] would scan (for the summary line).
pub fn source_count(root: &Path) -> Result<usize> {
    Ok(collect_sources(root)?.len())
}

/// Convenience for the CLI: load a baseline file, treating a missing
/// file as the empty baseline (zero accepted findings).
pub fn load_baseline(path: &Path) -> Result<Baseline> {
    match std::fs::read_to_string(path) {
        Ok(text) => Baseline::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display()))),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::default()),
        Err(e) => Err(e.into()),
    }
}

/// The default baseline location under a lint root.
pub fn default_baseline_path(root: &Path) -> PathBuf {
    root.join("lint.baseline")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_fixture_corpus_is_excluded_from_the_walk() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = collect_sources(root).unwrap();
        assert!(!files.is_empty());
        assert!(files.iter().all(|f| !f.contains("lint_fixtures")), "{files:?}");
        assert!(files.iter().any(|f| f == "rust/src/analysis/mod.rs"), "lints itself");
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted, "deterministic order");
    }

    #[test]
    fn missing_baseline_is_the_empty_baseline() {
        let b = load_baseline(Path::new("/nonexistent/lint.baseline")).unwrap();
        assert_eq!(b.total(), 0);
    }

    /// Regression: a directory entry whose file name is not valid UTF-8
    /// used to fall through the excluded-component check as `""` and be
    /// treated as lintable. The walk must skip it — and must still skip
    /// `lint_fixtures` alongside it.
    #[cfg(unix)]
    #[test]
    fn walk_skips_non_utf8_names_and_excluded_components() {
        use std::os::unix::ffi::OsStrExt;
        let root = std::env::temp_dir()
            .join(format!("scale_sim_lint_walk_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let src = root.join("rust/src");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(src.join("lib.rs"), "pub fn ok() {}\n").unwrap();
        // seeded violation inside the excluded fixture dir
        let fx = root.join("rust/tests/lint_fixtures");
        std::fs::create_dir_all(&fx).unwrap();
        std::fs::write(fx.join("bad.rs"), "fn f() { panic!(\"x\") }\n").unwrap();
        // a file whose name is invalid UTF-8 (lone 0x80 byte)
        let weird = src.join(std::ffi::OsStr::from_bytes(b"weird_\x80.rs"));
        std::fs::write(&weird, "fn g() { panic!(\"x\") }\n").unwrap();
        // a directory with a non-UTF-8 name containing a source
        let weird_dir = src.join(std::ffi::OsStr::from_bytes(b"dir_\x80"));
        std::fs::create_dir_all(&weird_dir).unwrap();
        std::fs::write(weird_dir.join("inner.rs"), "fn h() {}\n").unwrap();

        let files = collect_sources(&root).unwrap();
        assert_eq!(files, vec!["rust/src/lib.rs".to_string()], "{files:?}");
        std::fs::remove_dir_all(&root).unwrap();
    }
}
