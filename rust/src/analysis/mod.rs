//! In-tree static analysis: the `scale-sim lint` pass.
//!
//! Everything this reproduction promises rests on bit-exact
//! reproducibility — golden fixtures, dse journal fingerprints, the
//! "deprecated shims stay bit-identical" contract. This module is the
//! machine-checked enforcer of the invariants those promises rest on,
//! run over the repo's **own sources** as a hard CI gate (`ci.sh`):
//!
//! * [`lexer`] — a minimal hand-rolled Rust lexer (std-only, no
//!   syn/clippy: the offline build bans external crates), producing
//!   identifier/punct/string tokens with line numbers and guaranteed
//!   free of comment text.
//! * [`rules`] — the five rule visitors (R1 determinism, R2 lock
//!   discipline, R3 shim boundary, R4 panic hygiene, R5 golden-bless
//!   hygiene) with their exemption matrix.
//! * [`baseline`] — the checked-in ratchet (`lint.baseline`): existing
//!   violations are enumerated, new ones fail CI, fixed ones must be
//!   removed, so the count monotonically decreases.
//! * [`report`] — clickable `file:line:` diagnostic rendering.
//!
//! The pass lints itself: this module is `rust/src/` library code and
//! therefore subject to every rule it implements — which is why it
//! contains no `unwrap`/`expect`/`panic!` and no `HashMap`.

pub mod baseline;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

pub use baseline::{Baseline, Drift};
pub use rules::{classify, lint_source, FileClass, Finding, RuleId};

use crate::{Error, Result};

/// Directories scanned under the lint root.
const LINT_ROOTS: [&str; 3] = ["rust/src", "rust/tests", "rust/benches"];

/// Path components excluded from the scan: the fixture corpus *is*
/// seeded violations (each one asserted by `rust/tests/lint.rs`).
const EXCLUDED_COMPONENTS: [&str; 1] = ["lint_fixtures"];

/// Every `.rs` file the pass covers, as root-relative forward-slash
/// paths, sorted (deterministic walk order regardless of readdir).
pub fn collect_sources(root: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for sub in LINT_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, root, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if EXCLUDED_COMPONENTS.contains(&name) {
            continue;
        }
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(relative(root, &path));
        }
    }
    Ok(())
}

/// Root-relative path with forward slashes (the form findings, the
/// baseline, and diagnostics all use).
fn relative(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let s = rel.to_string_lossy();
    if s.contains('\\') {
        s.replace('\\', "/")
    } else {
        s.into_owned()
    }
}

/// Lint every source under `root`. Findings are sorted by
/// (file, line, rule) — byte-stable across runs and machines.
pub fn lint_root(root: &Path) -> Result<Vec<Finding>> {
    let files = collect_sources(root)?;
    if files.is_empty() {
        return Err(Error::Config(format!(
            "lint root {} contains no rust/src sources — pass --root at the repo root",
            root.display()
        )));
    }
    let mut out = Vec::new();
    for rel in &files {
        let text = std::fs::read_to_string(root.join(rel))?;
        out.extend(lint_source(rel, &text));
    }
    // lint_source sorts within a file; files arrive sorted
    Ok(out)
}

/// Number of files [`lint_root`] would scan (for the summary line).
pub fn source_count(root: &Path) -> Result<usize> {
    Ok(collect_sources(root)?.len())
}

/// Convenience for the CLI: load a baseline file, treating a missing
/// file as the empty baseline (zero accepted findings).
pub fn load_baseline(path: &Path) -> Result<Baseline> {
    match std::fs::read_to_string(path) {
        Ok(text) => Baseline::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display()))),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::default()),
        Err(e) => Err(e.into()),
    }
}

/// The default baseline location under a lint root.
pub fn default_baseline_path(root: &Path) -> PathBuf {
    root.join("lint.baseline")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_fixture_corpus_is_excluded_from_the_walk() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = collect_sources(root).unwrap();
        assert!(!files.is_empty());
        assert!(files.iter().all(|f| !f.contains("lint_fixtures")), "{files:?}");
        assert!(files.iter().any(|f| f == "rust/src/analysis/mod.rs"), "lints itself");
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted, "deterministic order");
    }

    #[test]
    fn missing_baseline_is_the_empty_baseline() {
        let b = load_baseline(Path::new("/nonexistent/lint.baseline")).unwrap();
        assert_eq!(b.total(), 0);
    }
}
