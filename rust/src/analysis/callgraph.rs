//! Crate-wide call-graph construction for the interprocedural rules.
//!
//! Every identifier occurrence in every file is matched against the
//! crate's [`items::FnItem`] table and resolved through one of four
//! contexts — method call (`x.f(..)`), qualified call (`a::b::f(..)`),
//! free call (`f(..)`), or bare mention (`f` passed as a value) — each
//! with an explicit **confidence** bit:
//!
//! * `confident` edges have exactly one plausible in-crate target and
//!   feed effect *propagation* (R6's transitive lock/I/O sets). A
//!   wrong confident edge would invent findings, so ambiguity always
//!   degrades to non-confident.
//! * non-confident edges (ambiguous methods, bare mentions, shadowed
//!   free names) still count for *reachability* (R8), where
//!   over-approximation merely keeps surface alive — the safe
//!   direction for a dead-code rule.
//!
//! Known limitations (documented in the README): no trait-object or
//! closure dispatch, no type inference — method calls resolve only
//! when the method name is unique crate-wide and not a common std
//! name; calls through `std` types never produce edges.

use std::collections::{BTreeMap, BTreeSet};

use super::items::{self, FileItems, FnItem};
use super::lexer::{Tok, Token};

/// One lexed + item-parsed source file.
pub struct ParsedSource {
    pub rel: String,
    pub toks: Vec<Token>,
    /// Per-token: inside a `#[cfg(test)]`-gated region.
    pub test_mask: Vec<bool>,
    pub items: FileItems,
}

/// A function node: which file it lives in plus its parsed item.
pub struct FnNode {
    pub file: usize,
    pub item: FnItem,
}

/// One call (or mention) edge.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    /// Token index of the callee name within the *caller's* file.
    pub tok: usize,
    pub line: u32,
    pub confident: bool,
}

/// The crate call graph.
pub struct Graph {
    pub fns: Vec<FnNode>,
    /// Sorted by (from, tok, to).
    pub edges: Vec<Edge>,
    /// Edge indices grouped by caller, in token order.
    pub calls_from: BTreeMap<usize, Vec<usize>>,
    /// Fns mentioned outside any fn body (statics, consts, macro
    /// arguments at item scope) — reachability roots.
    pub top_mentions: BTreeSet<usize>,
}

/// Rust keywords plus `self`/`Self`: never callee candidates.
const KEYWORDS: [&str; 40] = [
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else",
    "enum", "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match",
    "mod", "move", "mut", "pub", "ref", "return", "self", "static", "struct", "super",
    "trait", "true", "type", "unsafe", "use", "where", "while", "Self", "yield",
];

/// Method names so common on std types that a bare `x.name()` match
/// against a same-named crate method would usually be wrong. These
/// resolve as non-confident candidates only.
const STD_METHODS: [&str; 60] = [
    "abs", "all", "any", "append", "as_bytes", "as_str", "bytes", "chars", "clear",
    "clone", "cloned", "collect", "contains", "copied", "count", "drain", "drop",
    "ends_with", "entry", "enumerate", "expect", "extend", "filter", "find", "first",
    "flush", "fmt", "fold", "get", "insert", "is_empty", "iter", "join", "keys", "last",
    "len", "lock", "map", "max", "min", "next", "parse", "peek", "pop", "position",
    "push", "read", "recv", "remove", "rev", "send", "sort", "split", "starts_with",
    "sum", "take", "to_string", "trim", "unwrap", "write",
];

/// Build the crate call graph from every parsed source.
pub fn build(files: &[ParsedSource]) -> Graph {
    let mut fns: Vec<FnNode> = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        for item in &f.items.fns {
            fns.push(FnNode { file: fi, item: item.clone() });
        }
    }
    let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, n) in fns.iter().enumerate() {
        by_name.entry(n.item.name.as_str()).or_default().push(i);
    }
    let mut edges: Vec<Edge> = Vec::new();
    let mut top_mentions: BTreeSet<usize> = BTreeSet::new();
    for (fi, f) in files.iter().enumerate() {
        scan_file(fi, f, &fns, &by_name, &mut edges, &mut top_mentions);
    }
    edges.sort_by_key(|e| (e.from, e.tok, e.to));
    let mut calls_from: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, e) in edges.iter().enumerate() {
        calls_from.entry(e.from).or_default().push(i);
    }
    Graph { fns, edges, calls_from, top_mentions }
}

impl Graph {
    /// Fns reachable from `roots` over **all** edges (confident or not).
    pub fn reachable(&self, roots: &BTreeSet<usize>) -> BTreeSet<usize> {
        let mut seen = roots.clone();
        let mut queue: Vec<usize> = roots.iter().copied().collect();
        while let Some(f) = queue.pop() {
            if let Some(edge_ids) = self.calls_from.get(&f) {
                for &ei in edge_ids {
                    let to = self.edges[ei].to;
                    if seen.insert(to) {
                        queue.push(to);
                    }
                }
            }
        }
        seen
    }
}

/// Map every token of `file` to the fn whose body contains it.
/// Later-recorded (inner, nested) fns overwrite their enclosing fn's
/// claim, so tokens attribute to the innermost body.
fn owner_map(file: &ParsedSource, fns: &[FnNode], fi: usize) -> Vec<Option<usize>> {
    let mut owners: Vec<Option<usize>> = vec![None; file.toks.len()];
    for (gid, node) in fns.iter().enumerate() {
        if node.file != fi {
            continue;
        }
        if let Some((b0, b1)) = node.item.body {
            for slot in owners.iter_mut().take(b1.min(owners.len())).skip(b0) {
                *slot = Some(gid);
            }
        }
    }
    owners
}

fn scan_file(
    fi: usize,
    file: &ParsedSource,
    fns: &[FnNode],
    by_name: &BTreeMap<&str, Vec<usize>>,
    edges: &mut Vec<Edge>,
    top_mentions: &mut BTreeSet<usize>,
) {
    let owners = owner_map(file, fns, fi);
    let toks = &file.toks;
    for (i, t) in toks.iter().enumerate() {
        let Tok::Ident(name) = &t.tok else { continue };
        if KEYWORDS.contains(&name.as_str()) {
            continue;
        }
        let Some(cands) = by_name.get(name.as_str()) else { continue };
        // skip the declaration itself
        if i > 0 && toks[i - 1].is_ident("fn") {
            continue;
        }
        // skip macro names (`name!(..)`)
        if toks.get(i + 1).is_some_and(|u| u.is_punct('!')) {
            continue;
        }
        let prev_dot = i > 0 && toks[i - 1].is_punct('.');
        let prev_qual = i >= 2 && toks[i - 1].is_punct(':') && toks[i - 2].is_punct(':');
        let next_call = toks.get(i + 1).is_some_and(|u| u.is_punct('('));
        let owner = owners[i];
        let (targets, confident) = if prev_dot && next_call {
            resolve_method(name, cands, fns)
        } else if prev_qual && next_call {
            resolve_qualified(toks, i, name, cands, fns, file, owner)
        } else if next_call && !prev_dot && !prev_qual {
            resolve_free(name, cands, fns, file, fi)
        } else if !prev_dot {
            // bare mention — `f` as a value, re-export, or match arm;
            // counts for reachability only
            (cands.clone(), false)
        } else {
            // field access `x.f` without call parens
            continue;
        };
        let confident = confident && targets.len() == 1;
        match owner {
            Some(from) => {
                for to in targets {
                    edges.push(Edge { from, to, tok: i, line: t.line, confident });
                }
            }
            None => top_mentions.extend(targets),
        }
    }
}

/// `x.name(..)` — confident only if exactly one crate method bears the
/// name and the name is not a common std-type method.
fn resolve_method(name: &str, cands: &[usize], fns: &[FnNode]) -> (Vec<usize>, bool) {
    let methods: Vec<usize> = cands
        .iter()
        .copied()
        .filter(|&c| fns[c].item.has_receiver)
        .collect();
    if methods.is_empty() {
        return (cands.to_vec(), false);
    }
    let confident = methods.len() == 1 && !STD_METHODS.contains(&name);
    (methods, confident)
}

/// `a::b::name(..)` — resolve the path prefix through the caller file's
/// `use` map and module path.
fn resolve_qualified(
    toks: &[Token],
    i: usize,
    name: &str,
    cands: &[usize],
    fns: &[FnNode],
    file: &ParsedSource,
    owner: Option<usize>,
) -> (Vec<usize>, bool) {
    // collect path segments backwards: ident :: ident :: ... :: name
    let mut segs: Vec<String> = Vec::new();
    let mut k = i;
    while k >= 3 && toks[k - 1].is_punct(':') && toks[k - 2].is_punct(':') {
        match &toks[k - 3].tok {
            Tok::Ident(s) => {
                segs.push(s.clone());
                k -= 3;
            }
            // `<T as Trait>::name` or turbofish residue — give up on
            // the prefix, keep every candidate non-confidently
            _ => return (cands.to_vec(), false),
        }
    }
    segs.reverse();
    let Some(q) = segs.last().cloned() else {
        return (cands.to_vec(), false);
    };
    if q == "Self" {
        // method on the caller's own impl type
        let own_qual = owner.and_then(|o| fns[o].item.qual.clone());
        if let Some(own) = own_qual {
            let hits: Vec<usize> = cands
                .iter()
                .copied()
                .filter(|&c| fns[c].item.qual.as_deref() == Some(own.as_str()))
                .collect();
            if !hits.is_empty() {
                let confident = hits.len() == 1;
                return (hits, confident);
            }
        }
        return (cands.to_vec(), false);
    }
    if q.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
        // `Type::name` — resolve the type alias, match on impl qual
        let type_name = match file.items.uses.get(&q) {
            Some(path) => path.rsplit("::").next().unwrap_or(&q).to_string(),
            None => q,
        };
        let hits: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&c| fns[c].item.qual.as_deref() == Some(type_name.as_str()))
            .collect();
        if hits.is_empty() {
            return (cands.to_vec(), false);
        }
        let confident = hits.len() == 1;
        return (hits, confident);
    }
    // module-qualified free call: resolve the first segment through the
    // use map, then root the whole prefix against the file's module
    let mut resolved = segs.clone();
    if let Some(first) = resolved.first().cloned() {
        if let Some(path) = file.items.uses.get(&first) {
            let mut repl: Vec<String> = path.split("::").map(str::to_string).collect();
            repl.extend(resolved.drain(1..));
            resolved = repl;
        }
    }
    let prefix = items::resolve_path(&resolved, &file.items.module);
    let want = if prefix.is_empty() { name.to_string() } else { format!("{prefix}::{name}") };
    let hits: Vec<usize> = cands
        .iter()
        .copied()
        .filter(|&c| {
            let p = fns[c].item.path();
            p == want || p.ends_with(&format!("::{want}"))
        })
        .collect();
    if hits.is_empty() {
        return (cands.to_vec(), false);
    }
    let confident = hits.len() == 1;
    (hits, confident)
}

/// `name(..)` with no path — explicit `use` alias wins, then same-file
/// free fns, then glob imports, then a unique crate-wide free fn.
fn resolve_free(
    name: &str,
    cands: &[usize],
    fns: &[FnNode],
    file: &ParsedSource,
    fi: usize,
) -> (Vec<usize>, bool) {
    if let Some(path) = file.items.uses.get(name) {
        let hits: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&c| fns[c].item.path() == *path)
            .collect();
        if hits.len() == 1 {
            return (hits, true);
        }
        if !hits.is_empty() {
            return (hits, false);
        }
        // aliased to something we cannot see (std, re-export) —
        // conservatively keep every candidate, non-confident
        return (cands.to_vec(), false);
    }
    let same_file: Vec<usize> = cands
        .iter()
        .copied()
        .filter(|&c| fns[c].file == fi && fns[c].item.qual.is_none())
        .collect();
    if !same_file.is_empty() {
        let confident = same_file.len() == 1;
        return (same_file, confident);
    }
    let via_glob: Vec<usize> = cands
        .iter()
        .copied()
        .filter(|&c| {
            fns[c].item.qual.is_none()
                && file
                    .items
                    .globs
                    .iter()
                    .any(|g| fns[c].item.path() == format!("{g}::{name}"))
        })
        .collect();
    if via_glob.len() == 1 {
        return (via_glob, true);
    }
    if !via_glob.is_empty() {
        return (via_glob, false);
    }
    let free: Vec<usize> =
        cands.iter().copied().filter(|&c| fns[c].item.qual.is_none()).collect();
    if free.len() == 1 {
        return (free, true);
    }
    (cands.to_vec(), false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use crate::analysis::rules::test_region_mask as mask;

    fn parsed(rel: &str, src: &str) -> ParsedSource {
        let toks = lex(src);
        let test_mask = mask(&toks);
        let items = items::parse_file(rel, &toks);
        ParsedSource { rel: rel.to_string(), toks, test_mask, items }
    }

    fn edge_names(g: &Graph, from_name: &str) -> Vec<(String, bool)> {
        let from = g
            .fns
            .iter()
            .position(|n| n.item.name == from_name)
            .expect("caller in graph");
        g.edges
            .iter()
            .filter(|e| e.from == from)
            .map(|e| (g.fns[e.to].item.name.clone(), e.confident))
            .collect()
    }

    #[test]
    fn unique_method_calls_resolve_confidently() {
        let files = vec![
            parsed(
                "rust/src/a.rs",
                "pub struct S;\nimpl S { pub fn simulate_layer(&self) {} }\n",
            ),
            parsed(
                "rust/src/b.rs",
                "fn driver(s: &crate::a::S) { s.simulate_layer(); }\n",
            ),
        ];
        let g = build(&files);
        assert_eq!(edge_names(&g, "driver"), vec![("simulate_layer".to_string(), true)]);
    }

    #[test]
    fn std_method_names_stay_non_confident() {
        // `q.send(..)` matches a crate method named `send`, but `send`
        // is a common std method — the edge must not feed propagation.
        let files = vec![
            parsed("rust/src/a.rs", "pub struct Q;\nimpl Q { pub fn send(&self) {} }\n"),
            parsed("rust/src/b.rs", "fn driver(q: &crate::a::Q) { q.send(); }\n"),
        ];
        let g = build(&files);
        assert_eq!(edge_names(&g, "driver"), vec![("send".to_string(), false)]);
    }

    #[test]
    fn use_aliased_free_calls_resolve_through_the_alias() {
        let files = vec![
            parsed("rust/src/dse/journal.rs", "pub fn replay() {}\n"),
            parsed("rust/src/other.rs", "pub fn replay() {}\n"),
            parsed(
                "rust/src/cli.rs",
                "use crate::dse::journal::replay;\nfn run() { replay(); }\n",
            ),
        ];
        let g = build(&files);
        let edges = edge_names(&g, "run");
        assert_eq!(edges, vec![("replay".to_string(), true)]);
        let from = g.fns.iter().position(|n| n.item.name == "run").unwrap();
        let e = g.edges.iter().find(|e| e.from == from).unwrap();
        assert_eq!(g.fns[e.to].item.path(), "dse::journal::replay", "alias picked the right one");
    }

    #[test]
    fn module_qualified_calls_resolve_via_use_map() {
        let files = vec![
            parsed("rust/src/dse/journal.rs", "pub fn replay() {}\n"),
            parsed(
                "rust/src/cli.rs",
                "use crate::dse::journal;\nfn run() { journal::replay(); }\n",
            ),
        ];
        let g = build(&files);
        assert_eq!(edge_names(&g, "run"), vec![("replay".to_string(), true)]);
    }

    #[test]
    fn ambiguous_and_unresolvable_calls_degrade_to_non_confident() {
        // two crate fns named `helper`, called without qualification
        // from a third file: neither same-file nor unique — every
        // candidate kept, none confident (R8 sees them, R6 does not).
        let files = vec![
            parsed("rust/src/a.rs", "pub fn helper() {}\n"),
            parsed("rust/src/b.rs", "pub fn helper() {}\n"),
            parsed("rust/src/c.rs", "fn run() { helper(); }\n"),
        ];
        let g = build(&files);
        let edges = edge_names(&g, "run");
        assert_eq!(edges.len(), 2);
        assert!(edges.iter().all(|(_, conf)| !conf));
    }

    #[test]
    fn same_file_free_call_beats_crate_wide_duplicates() {
        let files = vec![
            parsed("rust/src/a.rs", "pub fn helper() {}\n"),
            parsed("rust/src/b.rs", "fn helper() {}\nfn run() { helper(); }\n"),
        ];
        let g = build(&files);
        let from = g.fns.iter().position(|n| n.item.name == "run").unwrap();
        let hits: Vec<&Edge> = g.edges.iter().filter(|e| e.from == from).collect();
        assert_eq!(hits.len(), 1);
        assert!(hits[0].confident);
        assert_eq!(g.fns[hits[0].to].file, 1, "resolved to the same-file fn");
    }

    #[test]
    fn bare_mentions_reach_but_do_not_propagate() {
        let files = vec![
            parsed("rust/src/a.rs", "pub fn callback() {}\n"),
            parsed("rust/src/b.rs", "fn run(f: fn()) { run(callback); }\n"),
        ];
        let g = build(&files);
        let edges = edge_names(&g, "run");
        assert!(edges.contains(&("callback".to_string(), false)), "{edges:?}");
        let roots: BTreeSet<usize> =
            g.fns.iter().position(|n| n.item.name == "run").into_iter().collect();
        let reach = g.reachable(&roots);
        let cb = g.fns.iter().position(|n| n.item.name == "callback").unwrap();
        assert!(reach.contains(&cb), "mentions count for reachability");
    }

    #[test]
    fn top_level_mentions_root_reachability() {
        let files = vec![
            parsed("rust/src/a.rs", "pub fn entry() {}\n"),
            parsed("rust/src/b.rs", "pub static HOOK: fn() = crate::a::entry;\n"),
        ];
        let g = build(&files);
        let entry = g.fns.iter().position(|n| n.item.name == "entry").unwrap();
        assert!(g.top_mentions.contains(&entry));
    }
}
