//! The rule visitors (R1–R5) of the in-tree static-analysis pass.
//!
//! Every rule walks the token stream of [`crate::analysis::lexer`] —
//! no syntax tree, so each check is an explicitly documented *token
//! heuristic*, tuned to this repo's idioms and pinned by the fixture
//! suite (`rust/tests/lint_fixtures/`). The repo invariants enforced:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1 `determinism`     | no `HashMap`/`HashSet` in serialization/fingerprint-bearing modules; no wall clock or entropy outside `util::bench`/`util::rng` |
//! | R2 `lock-discipline` | no `Mutex`/`RwLock` guard held across I/O or a second `lock()` |
//! | R3 `shim-boundary`   | engine-era modules never call the deprecated pre-engine shims |
//! | R4 `panic-hygiene`   | no `unwrap()`/`expect()`/`panic!` in library code |
//! | R5 `golden-bless`    | `BLESS_GOLDEN` is only read inside `rust/tests/golden*` |
//!
//! `#[cfg(test)]` regions are exempt from R1–R4 (tests may use
//! HashMaps, unwrap freely, and call shims to pin their equivalence);
//! R5 applies everywhere because a stray bless hook in a unit test is
//! exactly the bug the rule exists to catch.

use super::lexer::{lex, Tok, Token};

/// Rule identifier — `R1`..`R5`, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    R1,
    R2,
    R3,
    R4,
    R5,
}

impl RuleId {
    pub const ALL: [RuleId; 5] = [RuleId::R1, RuleId::R2, RuleId::R3, RuleId::R4, RuleId::R5];

    /// Short code used in baseline lines (`R1`).
    pub fn code(self) -> &'static str {
        match self {
            RuleId::R1 => "R1",
            RuleId::R2 => "R2",
            RuleId::R3 => "R3",
            RuleId::R4 => "R4",
            RuleId::R5 => "R5",
        }
    }

    /// Human slug used in diagnostics (`R1[determinism]`).
    pub fn slug(self) -> &'static str {
        match self {
            RuleId::R1 => "determinism",
            RuleId::R2 => "lock-discipline",
            RuleId::R3 => "shim-boundary",
            RuleId::R4 => "panic-hygiene",
            RuleId::R5 => "golden-bless",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.code() == s)
    }
}

/// One diagnostic: rule + `file:line` + message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: RuleId,
    /// Path relative to the lint root, forward slashes.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    pub message: String,
}

impl Finding {
    /// The clickable diagnostic form: `file:line: R1[determinism]: msg`.
    pub fn render(&self) -> String {
        format!(
            "{}:{}: {}[{}]: {}",
            self.file,
            self.line,
            self.rule.code(),
            self.rule.slug(),
            self.message
        )
    }
}

/// What kind of source file a path is — decides which rules apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    /// Library module under `rust/src/` (full rule set).
    Lib,
    /// Deprecated-shim module (`sim/`, `sweep/`, `scaleout/`,
    /// `coordinator/`, `config/topology.rs`): exempt from R3 — the
    /// shims may reference each other — but held to everything else.
    Shim,
    /// `rust/src/main.rs`: a CLI is allowed to panic on broken
    /// invariants (R4 exempt) but not to be nondeterministic.
    Main,
    /// Integration test under `rust/tests/` (only R5 applies).
    Test,
    /// Bench binary under `rust/benches/` (only R5 applies).
    Bench,
}

/// Module prefixes whose output feeds JSON writers, fingerprints,
/// journal lines, or golden-compared reports — where HashMap iteration
/// order would leak nondeterminism into bytes we promise are stable.
const DETERMINISM_CRITICAL: [&str; 5] = [
    "rust/src/dse/",
    "rust/src/server/",
    "rust/src/config/",
    "rust/src/report/",
    "rust/src/trace/",
];

/// Files allowed to touch wall-clock/entropy sources (R1's second half).
const CLOCK_EXEMPT: [&str; 2] = ["rust/src/util/bench.rs", "rust/src/util/rng.rs"];

/// Modules the shim-boundary rule (R3) protects: engine-era code that
/// must route through [`crate::engine`] rather than the deprecated
/// pre-engine entry points.
const SHIM_BOUNDARY_SCOPE: [&str; 4] = [
    "rust/src/engine/",
    "rust/src/dse/",
    "rust/src/server/",
    "rust/src/workload/",
];

/// Deprecated free functions (call position or `::`-qualified use).
const DEPRECATED_FNS: [&str; 10] = [
    "dataflow_sweep",
    "memory_sweep",
    "shape_sweep",
    "partition_filters",
    "node_layer",
    "node_layer_pixels",
    "scale_out_point",
    "compare_layer_with",
    "compare_layer",
    "compare_topology",
];

/// I/O methods a lock guard must not be held across (R2): TCP/file
/// writes, flushes, blocking reads, fsyncs.
const GUARDED_IO_CALLS: [&str; 9] = [
    "write_all",
    "write_fmt",
    "flush",
    "read_until",
    "read_line",
    "read_exact",
    "read_to_string",
    "sync_all",
    "sync_data",
];

/// Classify a lint-root-relative path (forward slashes).
pub fn classify(rel: &str) -> FileClass {
    if rel.starts_with("rust/tests/") {
        FileClass::Test
    } else if rel.starts_with("rust/benches/") {
        FileClass::Bench
    } else if rel == "rust/src/main.rs" {
        FileClass::Main
    } else if rel.starts_with("rust/src/sim/")
        || rel.starts_with("rust/src/sweep/")
        || rel.starts_with("rust/src/scaleout/")
        || rel.starts_with("rust/src/coordinator/")
        || rel == "rust/src/config/topology.rs"
    {
        FileClass::Shim
    } else {
        FileClass::Lib
    }
}

/// Lint one source file, addressed by its lint-root-relative path
/// (which decides the applicable rules). Findings are sorted by
/// (line, rule).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let toks = lex(src);
    let test_mask = test_region_mask(&toks);
    let class = classify(rel);
    let mut out = Vec::new();

    let prod = |i: usize| !test_mask.get(i).copied().unwrap_or(false);
    let in_prod_code = !matches!(class, FileClass::Test | FileClass::Bench);

    if in_prod_code {
        rule_r1(rel, &toks, &prod, &mut out);
        rule_r2(rel, &toks, &prod, &mut out);
        if class == FileClass::Lib && SHIM_BOUNDARY_SCOPE.iter().any(|p| rel.starts_with(p)) {
            rule_r3(rel, &toks, &prod, &mut out);
        }
        if matches!(class, FileClass::Lib | FileClass::Shim) {
            rule_r4(rel, &toks, &prod, &mut out);
        }
    }
    rule_r5(rel, &toks, &mut out);

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Mark every token inside a `#[cfg(test)]` item (a `mod { .. }`,
/// `fn { .. }`, `impl { .. }` body, or a `use ..;`). Returns one bool
/// per token: `true` = test-only code.
fn test_region_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if let Some(after_attr) = match_cfg_test_attr(toks, i) {
            // skip any further attributes between #[cfg(test)] and the item
            let mut j = after_attr;
            while toks.get(j).is_some_and(|t| t.is_punct('#')) {
                match skip_attr(toks, j) {
                    Some(n) => j = n,
                    None => break,
                }
            }
            if let Some(end) = item_end(toks, j) {
                for m in mask.iter_mut().take(end).skip(i) {
                    *m = true;
                }
                i = end;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// If tokens at `i` spell `#[cfg(test)]`, return the index just past
/// the closing `]`.
fn match_cfg_test_attr(toks: &[Token], i: usize) -> Option<usize> {
    let want: [&dyn Fn(&Token) -> bool; 7] = [
        &|t| t.is_punct('#'),
        &|t| t.is_punct('['),
        &|t| t.is_ident("cfg"),
        &|t| t.is_punct('('),
        &|t| t.is_ident("test"),
        &|t| t.is_punct(')'),
        &|t| t.is_punct(']'),
    ];
    for (k, pred) in want.iter().enumerate() {
        if !toks.get(i + k).is_some_and(|t| pred(t)) {
            return None;
        }
    }
    Some(i + want.len())
}

/// Skip a `#[...]` attribute starting at `i` (on the `#`); returns the
/// index just past its closing `]`.
fn skip_attr(toks: &[Token], i: usize) -> Option<usize> {
    if !toks.get(i)?.is_punct('#') || !toks.get(i + 1)?.is_punct('[') {
        return None;
    }
    let mut depth = 0i32;
    let mut j = i + 1;
    while let Some(t) = toks.get(j) {
        if t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

/// Find where the item starting at `i` ends: past the matching `}` of
/// its first brace (mod/fn/impl bodies), or past the `;` for `use`.
fn item_end(toks: &[Token], i: usize) -> Option<usize> {
    let mut j = i;
    while let Some(t) = toks.get(j) {
        if t.is_punct(';') {
            return Some(j + 1); // e.g. #[cfg(test)] use helpers::*;
        }
        if t.is_punct('{') {
            let mut depth = 0i32;
            let mut k = j;
            while let Some(u) = toks.get(k) {
                if u.is_punct('{') {
                    depth += 1;
                } else if u.is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        return Some(k + 1);
                    }
                }
                k += 1;
            }
            return None;
        }
        j += 1;
    }
    None
}

/// Skip a balanced `( .. )` group starting at `open` (on the `(`);
/// returns the index just past the matching `)`.
fn skip_parens(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut j = open;
    while let Some(t) = toks.get(j) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

fn ident_at<'t>(toks: &'t [Token], i: usize) -> Option<&'t str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

// ---------------------------------------------------------------- R1

fn rule_r1(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    let critical = DETERMINISM_CRITICAL.iter().any(|p| rel.starts_with(p));
    let clock_ok = CLOCK_EXEMPT.contains(&rel);
    for (i, t) in toks.iter().enumerate() {
        if !prod(i) {
            continue;
        }
        let Tok::Ident(name) = &t.tok else { continue };
        if critical && (name == "HashMap" || name == "HashSet") {
            out.push(Finding {
                rule: RuleId::R1,
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "{name} in a determinism-critical module: iteration order is \
                     nondeterministic and this module feeds JSON/fingerprint/golden \
                     output — use BTreeMap/BTreeSet (or sort before emitting)"
                ),
            });
        }
        if !clock_ok
            && matches!(
                name.as_str(),
                "SystemTime" | "thread_rng" | "from_entropy" | "getrandom" | "RandomState"
            )
        {
            out.push(Finding {
                rule: RuleId::R1,
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "{name} outside util::bench/util::rng: wall clocks and entropy \
                     sources break bit-exact reproducibility — thread timestamps in \
                     from the caller, or use util::rng's seeded generator"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- R2

/// A `let`-bound lock guard: `let [mut] g = <expr>.lock()[.unwrap()...];`
/// tracked from its `;` to the closing `}` of the enclosing block or an
/// explicit `drop(g)`. Within that span, an I/O call or a second
/// `.lock(` acquisition is flagged (first occurrence of each, so the
/// finding count per guard is stable under refactors of the span body).
///
/// Guard detection requires the lock chain to *end* the initializer
/// (only `.unwrap()`/`.expect(..)`/`.unwrap_or_else(..)` may follow):
/// `let x = m.lock().unwrap().field.clone();` copies data out and drops
/// the temporary guard at the `;`, so it is deliberately not tracked.
fn rule_r2(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    let mut i = 0usize;
    while i < toks.len() {
        if !(prod(i) && toks[i].is_ident("let")) {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
            j += 1;
        }
        let Some(guard_name) = ident_at(toks, j).map(str::to_string) else {
            i += 1;
            continue;
        };
        // scan the initializer up to its terminating `;`
        let Some((semi, acquires)) = initializer_acquires_guard(toks, j + 1) else {
            i += 1;
            continue;
        };
        if !acquires {
            i = semi + 1;
            continue;
        }
        scan_guard_span(rel, toks, prod, &guard_name, semi + 1, out);
        i = semi + 1;
    }
}

/// Walk `= <expr> ;` from just past the guard name. Returns the index
/// of the `;` and whether the initializer *ends* in a lock acquisition.
fn initializer_acquires_guard(toks: &[Token], mut j: usize) -> Option<(usize, bool)> {
    if !toks.get(j)?.is_punct('=') {
        return None;
    }
    j += 1;
    let mut acquired_at_tail = false;
    while let Some(t) = toks.get(j) {
        if t.is_punct(';') {
            return Some((j, acquired_at_tail));
        }
        if t.is_punct('(') {
            j = skip_parens(toks, j)?;
            continue;
        }
        if t.is_punct('.') {
            let name = ident_at(toks, j + 1);
            let after = j + 2;
            if toks.get(after).is_some_and(|t| t.is_punct('(')) {
                let past = skip_parens(toks, after)?;
                // acquisitions are zero-argument (`lock()`, RwLock's
                // `read()`/`write()`): an argument means io::Read::read
                // or similar, never a guard
                let no_args = past == after + 2;
                match name {
                    Some("lock") | Some("read") | Some("write") if no_args => {
                        acquired_at_tail = true
                    }
                    Some("unwrap") | Some("expect") | Some("unwrap_or_else") => {
                        // adapter over the guard: keeps the acquisition live
                    }
                    _ => acquired_at_tail = false,
                }
                j = past;
                // `?` after the chain changes nothing
                if toks.get(j).is_some_and(|t| t.is_punct('?')) {
                    j += 1;
                }
                continue;
            }
            // field access etc. — the tail is no longer the guard
            acquired_at_tail = false;
        }
        j += 1;
    }
    None
}

/// Flag I/O calls and second acquisitions between `start` and the `}`
/// closing the guard's block (or `drop(guard)`).
fn scan_guard_span(
    rel: &str,
    toks: &[Token],
    prod: &dyn Fn(usize) -> bool,
    guard: &str,
    start: usize,
    out: &mut Vec<Finding>,
) {
    let mut depth = 0i32;
    let mut io_flagged = false;
    let mut lock_flagged = false;
    let mut j = start;
    while let Some(t) = toks.get(j) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                return; // enclosing block closed: guard dropped
            }
        } else if t.is_ident("drop")
            && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
            && ident_at(toks, j + 2) == Some(guard)
            && toks.get(j + 3).is_some_and(|t| t.is_punct(')'))
        {
            return; // explicit early drop
        } else if t.is_punct('.') && prod(j) {
            if let Some(name) = ident_at(toks, j + 1) {
                if toks.get(j + 2).is_some_and(|t| t.is_punct('(')) {
                    if !io_flagged && GUARDED_IO_CALLS.contains(&name) {
                        io_flagged = true;
                        out.push(Finding {
                            rule: RuleId::R2,
                            file: rel.to_string(),
                            line: toks[j + 1].line,
                            message: format!(
                                "lock guard `{guard}` held across I/O call `{name}` — \
                                 a slow peer stalls every thread contending on this \
                                 lock; copy the data out, drop the guard, then do I/O"
                            ),
                        });
                    }
                    if !lock_flagged && name == "lock" {
                        lock_flagged = true;
                        out.push(Finding {
                            rule: RuleId::R2,
                            file: rel.to_string(),
                            line: toks[j + 1].line,
                            message: format!(
                                "lock guard `{guard}` held across a second `lock()` \
                                 acquisition — nested locking invites deadlock; drop \
                                 `{guard}` first or merge the critical sections"
                            ),
                        });
                    }
                }
            }
        }
        j += 1;
    }
}

// ---------------------------------------------------------------- R3

fn rule_r3(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    let flag = |out: &mut Vec<Finding>, line: u32, what: &str| {
        out.push(Finding {
            rule: RuleId::R3,
            file: rel.to_string(),
            line,
            message: format!(
                "engine-era module calls deprecated shim API `{what}` — route through \
                 crate::engine / the typed Workload IR instead (the shims exist only \
                 to keep pre-engine callers bit-identical)"
            ),
        });
    };
    let path_sep = |k: usize| {
        toks.get(k).is_some_and(|t| t.is_punct(':')) && toks.get(k + 1).is_some_and(|t| t.is_punct(':'))
    };
    for i in 0..toks.len() {
        if !prod(i) {
            continue;
        }
        let Some(name) = ident_at(toks, i) else { continue };
        if name == "Simulator" {
            flag(out, toks[i].line, "sim::Simulator");
            continue;
        }
        if DEPRECATED_FNS.contains(&name) {
            // call position or `::`-qualified mention (imports included)
            let called = toks.get(i + 1).is_some_and(|t| t.is_punct('('));
            let qualified = i >= 2 && path_sep(i - 2);
            if called || qualified {
                flag(out, toks[i].line, name);
            }
            continue;
        }
        if name == "coordinator" && path_sep(i + 1) && ident_at(toks, i + 3) == Some("run") {
            flag(out, toks[i].line, "coordinator::run");
            continue;
        }
        if name == "Topology" && path_sep(i + 1) {
            if let Some(m @ ("parse" | "from_file")) = ident_at(toks, i + 3) {
                flag(out, toks[i].line, &format!("Topology::{m}"));
            }
        }
    }
}

// ---------------------------------------------------------------- R4

fn rule_r4(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if !prod(i) {
            continue;
        }
        let Some(name) = ident_at(toks, i) else { continue };
        let flag = |out: &mut Vec<Finding>, what: &str| {
            out.push(Finding {
                rule: RuleId::R4,
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{what}` in library code — a poisoned lock or malformed input \
                     must surface as an Error (or recover via \
                     PoisonError::into_inner), not take the process down"
                ),
            });
        };
        match name {
            "unwrap" | "expect" => {
                let method_call = i >= 1
                    && toks[i - 1].is_punct('.')
                    && toks.get(i + 1).is_some_and(|t| t.is_punct('('));
                if method_call {
                    flag(out, &format!("{name}()"));
                }
            }
            "panic" => {
                if toks.get(i + 1).is_some_and(|t| t.is_punct('!')) {
                    flag(out, "panic!");
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------- R5

fn rule_r5(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    if rel.starts_with("rust/tests/golden") {
        return;
    }
    // assembled at runtime so the linter's own source never contains
    // the literal it hunts (the pass lints itself)
    let needle = concat!("BLESS_", "GOLDEN");
    for t in toks {
        let hit = match &t.tok {
            Tok::Ident(s) => s == needle,
            Tok::Str(s) => s.contains(needle),
            Tok::Punct(_) => false,
        };
        if hit {
            out.push(Finding {
                rule: RuleId::R5,
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "{needle} referenced outside rust/tests/golden* — blessing \
                     golden fixtures from anywhere else silently rewrites the \
                     regression contract"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find(rel: &str, src: &str) -> Vec<(RuleId, u32)> {
        lint_source(rel, src).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "\
use std::collections::HashMap;\n\
#[cfg(test)]\n\
mod tests {\n\
    use std::collections::HashMap;\n\
    fn f() { x.unwrap(); }\n\
}\n";
        let hits = find("rust/src/dse/x.rs", src);
        assert_eq!(hits, vec![(RuleId::R1, 1)], "only the non-test HashMap flags");
    }

    #[test]
    fn r2_does_not_flag_temporary_guards_or_dropped_guards() {
        let clean = "\
fn a(m: &Mutex<u32>) -> u32 { *m.lock().unwrap() }\n\
fn b(m: &Mutex<Vec<u8>>, w: &mut TcpStream) {\n\
    let data = m.lock().unwrap().clone();\n\
    let g = m.lock().unwrap();\n\
    drop(g);\n\
    w.write_all(&data).ok();\n\
}\n";
        assert!(find("rust/src/util/x.rs", clean).iter().all(|(r, _)| *r != RuleId::R2));
    }

    #[test]
    fn r3_ignores_non_deprecated_sweep_infrastructure() {
        let src = "\
use crate::sweep::parallel_map;\n\
fn f() { let t = crate::sweep::default_threads(); parallel_map(&v, t, |x| x); }\n";
        assert!(find("rust/src/engine/x.rs", src).is_empty());
    }

    #[test]
    fn r4_skips_unwrap_or_variants() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0).max(x.unwrap_or_else(|| 1)) }\n";
        assert!(find("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn classification_matrix() {
        assert_eq!(classify("rust/src/engine/mod.rs"), FileClass::Lib);
        assert_eq!(classify("rust/src/sweep/mod.rs"), FileClass::Shim);
        assert_eq!(classify("rust/src/config/topology.rs"), FileClass::Shim);
        assert_eq!(classify("rust/src/config/cfg.rs"), FileClass::Lib);
        assert_eq!(classify("rust/src/main.rs"), FileClass::Main);
        assert_eq!(classify("rust/tests/golden.rs"), FileClass::Test);
        assert_eq!(classify("rust/benches/perf_hotpath.rs"), FileClass::Bench);
    }
}
