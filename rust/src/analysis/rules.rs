//! The rule visitors (R1–R5) of the in-tree static-analysis pass.
//!
//! Every rule walks the token stream of [`crate::analysis::lexer`] —
//! no syntax tree, so each check is an explicitly documented *token
//! heuristic*, tuned to this repo's idioms and pinned by the fixture
//! suite (`rust/tests/lint_fixtures/`). The repo invariants enforced:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1 `determinism`     | no `HashMap`/`HashSet` in serialization/fingerprint-bearing modules; no wall clock or entropy outside `util::bench`/`util::rng` |
//! | R2 `lock-discipline` | no `Mutex`/`RwLock` guard held across I/O or a second `lock()` |
//! | R3 `shim-boundary`   | engine-era modules never call the deprecated pre-engine shims |
//! | R4 `panic-hygiene`   | no `unwrap()`/`expect()`/`panic!` in library code |
//! | R5 `golden-bless`    | `BLESS_GOLDEN` is only read inside `rust/tests/golden*` |
//! | R6 `lock-order`      | no guard held across a callee that (transitively) locks or does I/O; the global lock-order graph is acyclic |
//! | R7 `unit-taint`      | cycle-, wall-, and byte-valued quantities never mix in arithmetic or flow into the wrong metric sink |
//! | R8 `dead-surface`    | every protocol Request variant and CLI subcommand reaches a handler; no unreachable pub library fn |
//!
//! R1–R5 are per-file ([`lint_source`]); R6–R8 are **interprocedural**
//! ([`lint_interprocedural`]) — they parse every file's items, build
//! the crate call graph ([`super::callgraph`]) and propagate effects
//! along it, catching exactly the violations a single-function token
//! scan provably cannot (a guard held across a call into a function
//! that locks two files away).
//!
//! `#[cfg(test)]` regions are exempt from R1–R4 and R6–R7 (tests may
//! use HashMaps, unwrap freely, and call shims to pin their
//! equivalence); R5 applies everywhere because a stray bless hook in a
//! unit test is exactly the bug the rule exists to catch, and R8
//! treats test code as reachability *roots*.

use std::collections::{BTreeMap, BTreeSet};

use super::callgraph::{self, Graph, ParsedSource};
use super::items::{self, FnItem};
use super::lexer::{lex, Tok, Token};
use super::taint::{classify_ident, UnitClass};

/// Rule identifier — `R1`..`R8`, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    R7,
    R8,
}

impl RuleId {
    pub const ALL: [RuleId; 8] = [
        RuleId::R1,
        RuleId::R2,
        RuleId::R3,
        RuleId::R4,
        RuleId::R5,
        RuleId::R6,
        RuleId::R7,
        RuleId::R8,
    ];

    /// Short code used in baseline lines (`R1`).
    pub fn code(self) -> &'static str {
        match self {
            RuleId::R1 => "R1",
            RuleId::R2 => "R2",
            RuleId::R3 => "R3",
            RuleId::R4 => "R4",
            RuleId::R5 => "R5",
            RuleId::R6 => "R6",
            RuleId::R7 => "R7",
            RuleId::R8 => "R8",
        }
    }

    /// Human slug used in diagnostics (`R1[determinism]`).
    pub fn slug(self) -> &'static str {
        match self {
            RuleId::R1 => "determinism",
            RuleId::R2 => "lock-discipline",
            RuleId::R3 => "shim-boundary",
            RuleId::R4 => "panic-hygiene",
            RuleId::R5 => "golden-bless",
            RuleId::R6 => "lock-order",
            RuleId::R7 => "unit-taint",
            RuleId::R8 => "dead-surface",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.code() == s)
    }
}

/// One diagnostic: rule + `file:line` + message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: RuleId,
    /// Path relative to the lint root, forward slashes.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    pub message: String,
}

impl Finding {
    /// The clickable diagnostic form: `file:line: R1[determinism]: msg`.
    pub fn render(&self) -> String {
        format!(
            "{}:{}: {}[{}]: {}",
            self.file,
            self.line,
            self.rule.code(),
            self.rule.slug(),
            self.message
        )
    }
}

/// What kind of source file a path is — decides which rules apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    /// Library module under `rust/src/` (full rule set).
    Lib,
    /// Deprecated-shim module (`sim/`, `sweep/`, `scaleout/`,
    /// `coordinator/`, `config/topology.rs`): exempt from R3 — the
    /// shims may reference each other — but held to everything else.
    Shim,
    /// `rust/src/main.rs`: a CLI is allowed to panic on broken
    /// invariants (R4 exempt) but not to be nondeterministic.
    Main,
    /// Integration test under `rust/tests/` (only R5 applies).
    Test,
    /// Bench binary under `rust/benches/` (only R5 applies).
    Bench,
}

/// Module prefixes whose output feeds JSON writers, fingerprints,
/// journal lines, or golden-compared reports — where HashMap iteration
/// order would leak nondeterminism into bytes we promise are stable.
const DETERMINISM_CRITICAL: [&str; 5] = [
    "rust/src/dse/",
    "rust/src/server/",
    "rust/src/config/",
    "rust/src/report/",
    "rust/src/trace/",
];

/// Files allowed to touch wall-clock/entropy sources (R1's second half).
const CLOCK_EXEMPT: [&str; 2] = ["rust/src/util/bench.rs", "rust/src/util/rng.rs"];

/// Modules the shim-boundary rule (R3) protects: engine-era code that
/// must route through [`crate::engine`] rather than the deprecated
/// pre-engine entry points.
const SHIM_BOUNDARY_SCOPE: [&str; 4] = [
    "rust/src/engine/",
    "rust/src/dse/",
    "rust/src/server/",
    "rust/src/workload/",
];

/// Deprecated free functions (call position or `::`-qualified use).
const DEPRECATED_FNS: [&str; 10] = [
    "dataflow_sweep",
    "memory_sweep",
    "shape_sweep",
    "partition_filters",
    "node_layer",
    "node_layer_pixels",
    "scale_out_point",
    "compare_layer_with",
    "compare_layer",
    "compare_topology",
];

/// I/O methods a lock guard must not be held across (R2): TCP/file
/// writes, flushes, blocking reads, fsyncs.
const GUARDED_IO_CALLS: [&str; 9] = [
    "write_all",
    "write_fmt",
    "flush",
    "read_until",
    "read_line",
    "read_exact",
    "read_to_string",
    "sync_all",
    "sync_data",
];

/// Classify a lint-root-relative path (forward slashes).
pub fn classify(rel: &str) -> FileClass {
    if rel.starts_with("rust/tests/") {
        FileClass::Test
    } else if rel.starts_with("rust/benches/") {
        FileClass::Bench
    } else if rel == "rust/src/main.rs" {
        FileClass::Main
    } else if rel.starts_with("rust/src/sim/")
        || rel.starts_with("rust/src/sweep/")
        || rel.starts_with("rust/src/scaleout/")
        || rel.starts_with("rust/src/coordinator/")
        || rel == "rust/src/config/topology.rs"
    {
        FileClass::Shim
    } else {
        FileClass::Lib
    }
}

/// Lint one source file, addressed by its lint-root-relative path
/// (which decides the applicable rules). Findings are sorted by
/// (line, rule).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let toks = lex(src);
    let test_mask = test_region_mask(&toks);
    let class = classify(rel);
    let mut out = Vec::new();

    let prod = |i: usize| !test_mask.get(i).copied().unwrap_or(false);
    let in_prod_code = !matches!(class, FileClass::Test | FileClass::Bench);

    if in_prod_code {
        rule_r1(rel, &toks, &prod, &mut out);
        rule_r2(rel, &toks, &prod, &mut out);
        if class == FileClass::Lib && SHIM_BOUNDARY_SCOPE.iter().any(|p| rel.starts_with(p)) {
            rule_r3(rel, &toks, &prod, &mut out);
        }
        if matches!(class, FileClass::Lib | FileClass::Shim) {
            rule_r4(rel, &toks, &prod, &mut out);
        }
    }
    rule_r5(rel, &toks, &mut out);

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Mark every token inside a `#[cfg(test)]` item (a `mod { .. }`,
/// `fn { .. }`, `impl { .. }` body, or a `use ..;`). Returns one bool
/// per token: `true` = test-only code.
pub(crate) fn test_region_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if let Some(after_attr) = match_cfg_test_attr(toks, i) {
            // skip any further attributes between #[cfg(test)] and the item
            let mut j = after_attr;
            while toks.get(j).is_some_and(|t| t.is_punct('#')) {
                match skip_attr(toks, j) {
                    Some(n) => j = n,
                    None => break,
                }
            }
            if let Some(end) = item_end(toks, j) {
                for m in mask.iter_mut().take(end).skip(i) {
                    *m = true;
                }
                i = end;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// If tokens at `i` spell `#[cfg(test)]`, return the index just past
/// the closing `]`.
fn match_cfg_test_attr(toks: &[Token], i: usize) -> Option<usize> {
    let want: [&dyn Fn(&Token) -> bool; 7] = [
        &|t| t.is_punct('#'),
        &|t| t.is_punct('['),
        &|t| t.is_ident("cfg"),
        &|t| t.is_punct('('),
        &|t| t.is_ident("test"),
        &|t| t.is_punct(')'),
        &|t| t.is_punct(']'),
    ];
    for (k, pred) in want.iter().enumerate() {
        if !toks.get(i + k).is_some_and(|t| pred(t)) {
            return None;
        }
    }
    Some(i + want.len())
}

/// Skip a `#[...]` attribute starting at `i` (on the `#`); returns the
/// index just past its closing `]`.
fn skip_attr(toks: &[Token], i: usize) -> Option<usize> {
    if !toks.get(i)?.is_punct('#') || !toks.get(i + 1)?.is_punct('[') {
        return None;
    }
    let mut depth = 0i32;
    let mut j = i + 1;
    while let Some(t) = toks.get(j) {
        if t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

/// Find where the item starting at `i` ends: past the matching `}` of
/// its first brace (mod/fn/impl bodies), or past the `;` for `use`.
fn item_end(toks: &[Token], i: usize) -> Option<usize> {
    let mut j = i;
    while let Some(t) = toks.get(j) {
        if t.is_punct(';') {
            return Some(j + 1); // e.g. #[cfg(test)] use helpers::*;
        }
        if t.is_punct('{') {
            let mut depth = 0i32;
            let mut k = j;
            while let Some(u) = toks.get(k) {
                if u.is_punct('{') {
                    depth += 1;
                } else if u.is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        return Some(k + 1);
                    }
                }
                k += 1;
            }
            return None;
        }
        j += 1;
    }
    None
}

/// Skip a balanced `( .. )` group starting at `open` (on the `(`);
/// returns the index just past the matching `)`.
fn skip_parens(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut j = open;
    while let Some(t) = toks.get(j) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return Some(j + 1);
            }
        }
        j += 1;
    }
    None
}

fn ident_at<'t>(toks: &'t [Token], i: usize) -> Option<&'t str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

// ---------------------------------------------------------------- R1

fn rule_r1(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    let critical = DETERMINISM_CRITICAL.iter().any(|p| rel.starts_with(p));
    let clock_ok = CLOCK_EXEMPT.contains(&rel);
    for (i, t) in toks.iter().enumerate() {
        if !prod(i) {
            continue;
        }
        let Tok::Ident(name) = &t.tok else { continue };
        if critical && (name == "HashMap" || name == "HashSet") {
            out.push(Finding {
                rule: RuleId::R1,
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "{name} in a determinism-critical module: iteration order is \
                     nondeterministic and this module feeds JSON/fingerprint/golden \
                     output — use BTreeMap/BTreeSet (or sort before emitting)"
                ),
            });
        }
        if !clock_ok
            && matches!(
                name.as_str(),
                "SystemTime" | "thread_rng" | "from_entropy" | "getrandom" | "RandomState"
            )
        {
            out.push(Finding {
                rule: RuleId::R1,
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "{name} outside util::bench/util::rng: wall clocks and entropy \
                     sources break bit-exact reproducibility — thread timestamps in \
                     from the caller, or use util::rng's seeded generator"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- R2

/// A `let`-bound lock guard: `let [mut] g = <expr>.lock()[.unwrap()...];`
/// tracked from its `;` to the closing `}` of the enclosing block or an
/// explicit `drop(g)`. Within that span, an I/O call or a second
/// `.lock(` acquisition is flagged (first occurrence of each, so the
/// finding count per guard is stable under refactors of the span body).
///
/// Guard detection requires the lock chain to *end* the initializer
/// (only `.unwrap()`/`.expect(..)`/`.unwrap_or_else(..)` may follow):
/// `let x = m.lock().unwrap().field.clone();` copies data out and drops
/// the temporary guard at the `;`, so it is deliberately not tracked.
fn rule_r2(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    let mut i = 0usize;
    while i < toks.len() {
        if !(prod(i) && toks[i].is_ident("let")) {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
            j += 1;
        }
        let Some(guard_name) = ident_at(toks, j).map(str::to_string) else {
            i += 1;
            continue;
        };
        // scan the initializer up to its terminating `;`
        let Some((semi, acquires)) = initializer_acquires_guard(toks, j + 1) else {
            i += 1;
            continue;
        };
        if !acquires {
            i = semi + 1;
            continue;
        }
        scan_guard_span(rel, toks, prod, &guard_name, semi + 1, out);
        i = semi + 1;
    }
}

/// Walk `= <expr> ;` from just past the guard name. Returns the index
/// of the `;` and whether the initializer *ends* in a lock acquisition.
fn initializer_acquires_guard(toks: &[Token], mut j: usize) -> Option<(usize, bool)> {
    if !toks.get(j)?.is_punct('=') {
        return None;
    }
    j += 1;
    let mut acquired_at_tail = false;
    while let Some(t) = toks.get(j) {
        if t.is_punct(';') {
            return Some((j, acquired_at_tail));
        }
        if t.is_punct('(') {
            j = skip_parens(toks, j)?;
            continue;
        }
        if t.is_punct('.') {
            let name = ident_at(toks, j + 1);
            let after = j + 2;
            if toks.get(after).is_some_and(|t| t.is_punct('(')) {
                let past = skip_parens(toks, after)?;
                // acquisitions are zero-argument (`lock()`, RwLock's
                // `read()`/`write()`): an argument means io::Read::read
                // or similar, never a guard
                let no_args = past == after + 2;
                match name {
                    Some("lock") | Some("read") | Some("write") if no_args => {
                        acquired_at_tail = true
                    }
                    Some("unwrap") | Some("expect") | Some("unwrap_or_else") => {
                        // adapter over the guard: keeps the acquisition live
                    }
                    _ => acquired_at_tail = false,
                }
                j = past;
                // `?` after the chain changes nothing
                if toks.get(j).is_some_and(|t| t.is_punct('?')) {
                    j += 1;
                }
                continue;
            }
            // field access etc. — the tail is no longer the guard
            acquired_at_tail = false;
        }
        j += 1;
    }
    None
}

/// Flag I/O calls and second acquisitions between `start` and the `}`
/// closing the guard's block (or `drop(guard)`).
fn scan_guard_span(
    rel: &str,
    toks: &[Token],
    prod: &dyn Fn(usize) -> bool,
    guard: &str,
    start: usize,
    out: &mut Vec<Finding>,
) {
    let mut depth = 0i32;
    let mut io_flagged = false;
    let mut lock_flagged = false;
    let mut j = start;
    while let Some(t) = toks.get(j) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                return; // enclosing block closed: guard dropped
            }
        } else if t.is_ident("drop")
            && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
            && ident_at(toks, j + 2) == Some(guard)
            && toks.get(j + 3).is_some_and(|t| t.is_punct(')'))
        {
            return; // explicit early drop
        } else if t.is_punct('.') && prod(j) {
            if let Some(name) = ident_at(toks, j + 1) {
                if toks.get(j + 2).is_some_and(|t| t.is_punct('(')) {
                    if !io_flagged && GUARDED_IO_CALLS.contains(&name) {
                        io_flagged = true;
                        out.push(Finding {
                            rule: RuleId::R2,
                            file: rel.to_string(),
                            line: toks[j + 1].line,
                            message: format!(
                                "lock guard `{guard}` held across I/O call `{name}` — \
                                 a slow peer stalls every thread contending on this \
                                 lock; copy the data out, drop the guard, then do I/O"
                            ),
                        });
                    }
                    if !lock_flagged && name == "lock" {
                        lock_flagged = true;
                        out.push(Finding {
                            rule: RuleId::R2,
                            file: rel.to_string(),
                            line: toks[j + 1].line,
                            message: format!(
                                "lock guard `{guard}` held across a second `lock()` \
                                 acquisition — nested locking invites deadlock; drop \
                                 `{guard}` first or merge the critical sections"
                            ),
                        });
                    }
                }
            }
        }
        j += 1;
    }
}

// ---------------------------------------------------------------- R3

fn rule_r3(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    let flag = |out: &mut Vec<Finding>, line: u32, what: &str| {
        out.push(Finding {
            rule: RuleId::R3,
            file: rel.to_string(),
            line,
            message: format!(
                "engine-era module calls deprecated shim API `{what}` — route through \
                 crate::engine / the typed Workload IR instead (the shims exist only \
                 to keep pre-engine callers bit-identical)"
            ),
        });
    };
    let path_sep = |k: usize| {
        toks.get(k).is_some_and(|t| t.is_punct(':')) && toks.get(k + 1).is_some_and(|t| t.is_punct(':'))
    };
    for i in 0..toks.len() {
        if !prod(i) {
            continue;
        }
        let Some(name) = ident_at(toks, i) else { continue };
        if name == "Simulator" {
            flag(out, toks[i].line, "sim::Simulator");
            continue;
        }
        if DEPRECATED_FNS.contains(&name) {
            // call position or `::`-qualified mention (imports included)
            let called = toks.get(i + 1).is_some_and(|t| t.is_punct('('));
            let qualified = i >= 2 && path_sep(i - 2);
            if called || qualified {
                flag(out, toks[i].line, name);
            }
            continue;
        }
        if name == "coordinator" && path_sep(i + 1) && ident_at(toks, i + 3) == Some("run") {
            flag(out, toks[i].line, "coordinator::run");
            continue;
        }
        if name == "Topology" && path_sep(i + 1) {
            if let Some(m @ ("parse" | "from_file")) = ident_at(toks, i + 3) {
                flag(out, toks[i].line, &format!("Topology::{m}"));
            }
        }
    }
}

// ---------------------------------------------------------------- R4

fn rule_r4(rel: &str, toks: &[Token], prod: &dyn Fn(usize) -> bool, out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if !prod(i) {
            continue;
        }
        let Some(name) = ident_at(toks, i) else { continue };
        let flag = |out: &mut Vec<Finding>, what: &str| {
            out.push(Finding {
                rule: RuleId::R4,
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{what}` in library code — a poisoned lock or malformed input \
                     must surface as an Error (or recover via \
                     PoisonError::into_inner), not take the process down"
                ),
            });
        };
        match name {
            "unwrap" | "expect" => {
                let method_call = i >= 1
                    && toks[i - 1].is_punct('.')
                    && toks.get(i + 1).is_some_and(|t| t.is_punct('('));
                if method_call {
                    flag(out, &format!("{name}()"));
                }
            }
            "panic" => {
                if toks.get(i + 1).is_some_and(|t| t.is_punct('!')) {
                    flag(out, "panic!");
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------- R5

fn rule_r5(rel: &str, toks: &[Token], out: &mut Vec<Finding>) {
    if rel.starts_with("rust/tests/golden") {
        return;
    }
    // assembled at runtime so the linter's own source never contains
    // the literal it hunts (the pass lints itself)
    let needle = concat!("BLESS_", "GOLDEN");
    for t in toks {
        let hit = match &t.tok {
            Tok::Ident(s) => s == needle,
            Tok::Str(s) => s.contains(needle),
            Tok::Punct(_) => false,
        };
        if hit {
            out.push(Finding {
                rule: RuleId::R5,
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "{needle} referenced outside rust/tests/golden* — blessing \
                     golden fixtures from anywhere else silently rewrites the \
                     regression contract"
                ),
            });
        }
    }
}

// ------------------------------------------------- interprocedural

/// Effect summary of one fn: the lock identities it (transitively)
/// acquires and whether it (transitively) performs guarded I/O.
#[derive(Clone, Debug, Default)]
struct FnEffects {
    locks: BTreeSet<String>,
    io: bool,
}

/// Run the interprocedural rule families (R6–R8) over the whole crate:
/// parse every file's items, build the call graph, propagate lock/I-O
/// effects along confident edges, then apply the three rule drivers.
/// `sources` holds `(root-relative path, text)` pairs; findings come
/// back unsorted ([`super::lint_crate`] orders them globally).
pub fn lint_interprocedural(sources: &[(String, String)]) -> Vec<Finding> {
    let files: Vec<ParsedSource> = sources
        .iter()
        .map(|(rel, text)| {
            let toks = lex(text);
            let test_mask = test_region_mask(&toks);
            let parsed = items::parse_file(rel, &toks);
            ParsedSource { rel: rel.clone(), toks, test_mask, items: parsed }
        })
        .collect();
    let graph = callgraph::build(&files);
    let mut out = Vec::new();
    rule_r6(&files, &graph, &mut out);
    for f in &files {
        rule_r7(f, &mut out);
    }
    rule_r8(&files, &graph, &mut out);
    out
}

fn prod_at(file: &ParsedSource, i: usize) -> bool {
    !file.test_mask.get(i).copied().unwrap_or(false)
}

// ---------------------------------------------------------------- R6

fn rule_r6(files: &[ParsedSource], graph: &Graph, out: &mut Vec<Finding>) {
    // 1. direct per-fn effects
    let mut eff: Vec<FnEffects> = graph
        .fns
        .iter()
        .map(|node| direct_effects(&files[node.file], &node.item))
        .collect();
    // 2. propagate to a fixpoint along *confident* edges only — an
    //    ambiguous edge feeding propagation would invent findings
    loop {
        let mut changed = false;
        for e in &graph.edges {
            if !e.confident {
                continue;
            }
            let callee = eff[e.to].clone();
            let caller = &mut eff[e.from];
            if callee.io && !caller.io {
                caller.io = true;
                changed = true;
            }
            for l in callee.locks {
                if caller.locks.insert(l) {
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    // 3. per-guard span scans + lock-order edge collection
    let mut order: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
    for (gid, node) in graph.fns.iter().enumerate() {
        let file = &files[node.file];
        if matches!(classify(&file.rel), FileClass::Test | FileClass::Bench) {
            continue;
        }
        if !prod_at(file, node.item.decl_tok) {
            continue;
        }
        guard_spans(file, &node.item, gid, graph, &eff, &mut order, out);
    }
    // 4. cycles in the global lock-order graph
    order_cycles(&order, out);
}

/// Token-level effects of one fn body (prod tokens only).
fn direct_effects(file: &ParsedSource, item: &FnItem) -> FnEffects {
    let mut eff = FnEffects::default();
    let Some((b0, b1)) = item.body else { return eff };
    let toks = &file.toks;
    for j in b0..b1.min(toks.len()) {
        if !prod_at(file, j) || !toks[j].is_punct('.') {
            continue;
        }
        if let Some(id) = lock_acquisition_at(toks, j, item.qual.as_deref()) {
            eff.locks.insert(id);
        }
        if let Some(name) = ident_at(toks, j + 1) {
            if toks.get(j + 2).is_some_and(|t| t.is_punct('(')) && GUARDED_IO_CALLS.contains(&name)
            {
                eff.io = true;
            }
        }
    }
    eff
}

/// If the `.` at `dot` begins a zero-argument `lock()`/`read()`/
/// `write()` acquisition, return the lock's identity: the receiver's
/// ident chain (leading `self` replaced by the impl type), dot-joined —
/// `self.state.lock()` inside `impl Shared` is `"Shared.state"`.
/// Non-ident receivers (`(*x).lock()`, `helper().lock()`) return
/// `None`: better to miss an order edge than to invent one.
fn lock_acquisition_at(toks: &[Token], dot: usize, qual: Option<&str>) -> Option<String> {
    let name = ident_at(toks, dot + 1)?;
    if !matches!(name, "lock" | "read" | "write") {
        return None;
    }
    if !(toks.get(dot + 2).is_some_and(|t| t.is_punct('('))
        && toks.get(dot + 3).is_some_and(|t| t.is_punct(')')))
    {
        return None;
    }
    let mut segs: Vec<String> = Vec::new();
    let mut k = dot; // toks[k] is the `.` whose receiver chain we walk
    loop {
        let Some(id) = k.checked_sub(1).and_then(|p| ident_at(toks, p)) else {
            return None;
        };
        segs.push(id.to_string());
        if k >= 3 && toks[k - 2].is_punct('.') && ident_at(toks, k - 3).is_some() {
            k -= 2;
        } else {
            break;
        }
    }
    segs.reverse();
    if segs.first().map(String::as_str) == Some("self") {
        if let Some(q) = qual {
            segs[0] = q.to_string();
        }
    }
    Some(segs.join("."))
}

/// Find `let`-bound guards in one fn body; flag confident calls into
/// lock-acquiring or I/O-performing callees made while the guard is
/// held, and record lock-order edges for the global cycle check.
fn guard_spans(
    file: &ParsedSource,
    item: &FnItem,
    gid: usize,
    graph: &Graph,
    eff: &[FnEffects],
    order: &mut BTreeMap<(String, String), (String, u32)>,
    out: &mut Vec<Finding>,
) {
    let Some((b0, b1)) = item.body else { return };
    let toks = &file.toks;
    let no_edges: Vec<usize> = Vec::new();
    let edge_ids = graph.calls_from.get(&gid).unwrap_or(&no_edges);
    let mut i = b0;
    while i < b1.min(toks.len()) {
        if !(prod_at(file, i) && toks[i].is_ident("let")) {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
            j += 1;
        }
        let Some(guard) = ident_at(toks, j).map(str::to_string) else {
            i += 1;
            continue;
        };
        let Some((semi, acquires)) = initializer_acquires_guard(toks, j + 1) else {
            i += 1;
            continue;
        };
        if !acquires {
            i = semi + 1;
            continue;
        }
        // identity of the held lock: last acquisition in the initializer
        let mut held: Option<String> = None;
        for d in j + 1..semi {
            if toks[d].is_punct('.') {
                if let Some(id) = lock_acquisition_at(toks, d, item.qual.as_deref()) {
                    held = Some(id);
                }
            }
        }
        let end = guard_span_end(toks, &guard, semi + 1, b1);
        // direct second acquisitions inside the span: R2 flags the
        // violation itself; R6 records only the ordering
        if let Some(h) = &held {
            for d in semi + 1..end {
                if !toks[d].is_punct('.') {
                    continue;
                }
                if let Some(id) = lock_acquisition_at(toks, d, item.qual.as_deref()) {
                    record_order(order, h, &id, &file.rel, toks[d].line);
                }
            }
        }
        // confident calls made while the guard is held
        let mut call_flagged = false;
        let mut io_flagged = false;
        for &ei in edge_ids {
            let e = &graph.edges[ei];
            if !e.confident || e.tok <= semi || e.tok >= end {
                continue;
            }
            let callee = &graph.fns[e.to].item;
            let ce = &eff[e.to];
            if !ce.locks.is_empty() {
                if !call_flagged {
                    call_flagged = true;
                    let locks: Vec<&str> = ce.locks.iter().map(String::as_str).collect();
                    out.push(Finding {
                        rule: RuleId::R6,
                        file: file.rel.clone(),
                        line: e.line,
                        message: format!(
                            "lock guard `{guard}` held across call to `{}`, which \
                             (transitively) acquires {} — invisible to the \
                             same-function scan (R2); drop the guard before the call",
                            callee.path(),
                            locks.join(", "),
                        ),
                    });
                }
                if let Some(h) = &held {
                    for l in &ce.locks {
                        record_order(order, h, l, &file.rel, e.line);
                    }
                }
            }
            if ce.io && !io_flagged {
                io_flagged = true;
                out.push(Finding {
                    rule: RuleId::R6,
                    file: file.rel.clone(),
                    line: e.line,
                    message: format!(
                        "lock guard `{guard}` held across call to `{}`, which \
                         (transitively) performs I/O — a slow peer stalls every \
                         thread contending on this lock",
                        callee.path(),
                    ),
                });
            }
        }
        i = semi + 1;
    }
}

/// Index of the first token at which the guard bound before `start` is
/// no longer live: the enclosing block's `}`, an explicit
/// `drop(guard)`, or the body end.
fn guard_span_end(toks: &[Token], guard: &str, start: usize, body_end: usize) -> usize {
    let mut depth = 0i32;
    let mut j = start;
    while j < body_end.min(toks.len()) {
        let t = &toks[j];
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                return j;
            }
        } else if t.is_ident("drop")
            && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
            && ident_at(toks, j + 2) == Some(guard)
            && toks.get(j + 3).is_some_and(|t| t.is_punct(')'))
        {
            return j;
        }
        j += 1;
    }
    j
}

/// Record one lock-order edge, keeping the lexicographically smallest
/// site per edge (deterministic finding anchors across runs).
fn record_order(
    order: &mut BTreeMap<(String, String), (String, u32)>,
    from: &str,
    to: &str,
    rel: &str,
    line: u32,
) {
    if from == to {
        return; // double-lock: reported as a finding, not an ordering
    }
    let key = (from.to_string(), to.to_string());
    let site = (rel.to_string(), line);
    match order.get(&key) {
        Some(existing) if *existing <= site => {}
        _ => {
            order.insert(key, site);
        }
    }
}

/// Walk the lock-order graph from `start`; returns every node
/// reachable through at least one edge.
fn order_reach<'a>(
    adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    start: &str,
) -> BTreeSet<&'a str> {
    let mut seen: BTreeSet<&'a str> = BTreeSet::new();
    let mut queue: Vec<&str> = vec![start];
    while let Some(n) = queue.pop() {
        if let Some(next) = adj.get(n) {
            for &m in next {
                if seen.insert(m) {
                    queue.push(m);
                }
            }
        }
    }
    seen
}

/// Fail on any strongly-connected component of size > 1 in the global
/// lock-order graph: two locks mutually ordered means two threads can
/// take them in opposite orders and deadlock.
fn order_cycles(order: &BTreeMap<(String, String), (String, u32)>, out: &mut Vec<Finding>) {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (from, to) in order.keys() {
        adj.entry(from).or_default().insert(to);
        adj.entry(to).or_default();
    }
    let nodes: Vec<&str> = adj.keys().copied().collect();
    let mut assigned: BTreeSet<&str> = BTreeSet::new();
    for node in nodes {
        if assigned.contains(node) {
            continue;
        }
        let fwd = order_reach(&adj, node);
        if !fwd.contains(node) {
            continue; // no cycle through this node
        }
        let scc: BTreeSet<&str> = fwd
            .iter()
            .copied()
            .filter(|&m| order_reach(&adj, m).contains(node))
            .collect();
        assigned.extend(scc.iter().copied());
        if scc.len() < 2 {
            continue; // self-edges are filtered at record time
        }
        // anchor at the smallest site among the component's edges
        let mut site: Option<&(String, u32)> = None;
        for ((f, t), s) in order {
            if scc.contains(f.as_str()) && scc.contains(t.as_str()) {
                match site {
                    Some(cur) if cur <= s => {}
                    _ => site = Some(s),
                }
            }
        }
        let Some((file, line)) = site else { continue };
        let ring: Vec<&str> = scc.iter().copied().collect();
        let mut cycle = ring.join(" -> ");
        cycle.push_str(" -> ");
        cycle.push_str(ring[0]);
        out.push(Finding {
            rule: RuleId::R6,
            file: file.clone(),
            line: *line,
            message: format!(
                "lock-order cycle: {cycle} — threads acquiring these locks in \
                 different orders can deadlock; pick one global order"
            ),
        });
    }
}

// ---------------------------------------------------------------- R7

/// Files exempt from R7: span payloads in `obs/trace.rs` deliberately
/// carry simulated cycles in wire fields whose names say `us`
/// (documented there — the trace *renders* cycles on a time axis).
const TAINT_EXEMPT: [&str; 1] = ["rust/src/obs/trace.rs"];

fn rule_r7(file: &ParsedSource, out: &mut Vec<Finding>) {
    if matches!(classify(&file.rel), FileClass::Test | FileClass::Bench)
        || TAINT_EXEMPT.contains(&file.rel.as_str())
    {
        return;
    }
    let toks = &file.toks;
    for (i, t) in toks.iter().enumerate() {
        if !prod_at(file, i) {
            continue;
        }
        match &t.tok {
            Tok::Punct(op @ ('+' | '-')) => {
                // `->` return arrows are not subtraction
                if *op == '-' && toks.get(i + 1).is_some_and(|t| t.is_punct('>')) {
                    continue;
                }
                let Some(lhs) = i.checked_sub(1).and_then(|p| ident_at(toks, p)) else {
                    continue;
                };
                let Some(a) = classify_ident(lhs) else { continue };
                // `a += b` lexes as `+` `=`; the operand is one further on
                let mut j = i + 1;
                if toks.get(j).is_some_and(|t| t.is_punct('=')) {
                    j += 1;
                }
                if ident_at(toks, j).is_none() {
                    continue;
                }
                // follow the dotted chain to its final field/method name
                while toks.get(j + 1).is_some_and(|t| t.is_punct('.'))
                    && ident_at(toks, j + 2).is_some()
                {
                    j += 2;
                }
                let Some(rhs) = ident_at(toks, j) else { continue };
                let Some(b) = classify_ident(rhs) else { continue };
                if a != b {
                    out.push(Finding {
                        rule: RuleId::R7,
                        file: file.rel.clone(),
                        line: t.line,
                        message: format!(
                            "`{lhs}` is {}-valued but `{rhs}` is {}-valued — the two \
                             timelines (and byte counts) must not meet in arithmetic; \
                             convert explicitly or rename the mislabelled quantity",
                            a.name(),
                            b.name(),
                        ),
                    });
                }
            }
            Tok::Ident(sink)
                if matches!(sink.as_str(), "observe_seconds" | "observe_simulate_latency") =>
            {
                // a call site, not the method's own declaration
                if !toks.get(i + 1).is_some_and(|t| t.is_punct('(')) {
                    continue;
                }
                if i.checked_sub(1).and_then(|p| ident_at(toks, p)) == Some("fn") {
                    continue;
                }
                let Some(close) = skip_parens(toks, i + 1) else { continue };
                for j in i + 2..close - 1 {
                    if let Some(arg) = ident_at(toks, j) {
                        if classify_ident(arg) == Some(UnitClass::Cycles) {
                            out.push(Finding {
                                rule: RuleId::R7,
                                file: file.rel.clone(),
                                line: toks[j].line,
                                message: format!(
                                    "cycle-valued `{arg}` fed to wall-time sink \
                                     `{sink}` — simulated time in a wall-clock \
                                     histogram renders latency dashboards wrong"
                                ),
                            });
                            break;
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------- R8

fn rule_r8(files: &[ParsedSource], graph: &Graph, out: &mut Vec<Finding>) {
    r8_proto_variants(files, out);
    // reachability roots: every fn in a binary/test/bench file, every
    // #[cfg(test)] fn in a lib file, and every item-level mention
    let mut roots: BTreeSet<usize> = graph.top_mentions.clone();
    let mut main_fns: Vec<usize> = Vec::new();
    for (gid, node) in graph.fns.iter().enumerate() {
        let file = &files[node.file];
        let class = classify(&file.rel);
        match class {
            FileClass::Main | FileClass::Test | FileClass::Bench => {
                roots.insert(gid);
                if class == FileClass::Main {
                    main_fns.push(gid);
                }
            }
            _ => {
                if !prod_at(file, node.item.decl_tok) {
                    roots.insert(gid);
                }
            }
        }
    }
    let live = graph.reachable(&roots);
    // (b) CLI dispatch: cmd_* handlers must be reachable from main itself
    let main_roots: BTreeSet<usize> = main_fns
        .iter()
        .copied()
        .filter(|&g| graph.fns[g].item.name == "main")
        .collect();
    let from_main = graph.reachable(&main_roots);
    for &gid in &main_fns {
        let node = &graph.fns[gid];
        if node.item.name.starts_with("cmd_") && !from_main.contains(&gid) {
            out.push(Finding {
                rule: RuleId::R8,
                file: files[node.file].rel.clone(),
                line: node.item.line,
                message: format!(
                    "CLI subcommand handler `{}` is unreachable from main — the \
                     dispatch match no longer routes to it",
                    node.item.name,
                ),
            });
        }
    }
    // (c) dead public surface
    for (gid, node) in graph.fns.iter().enumerate() {
        let file = &files[node.file];
        if !matches!(classify(&file.rel), FileClass::Lib | FileClass::Shim) {
            continue;
        }
        let it = &node.item;
        if !it.is_pub || it.body.is_none() || !prod_at(file, it.decl_tok) {
            continue;
        }
        if !live.contains(&gid) {
            out.push(Finding {
                rule: RuleId::R8,
                file: file.rel.clone(),
                line: it.line,
                message: format!(
                    "dead public surface: `{}` is unreachable from main, any \
                     test, bench, or item-level mention — delete it or cover it",
                    it.path(),
                ),
            });
        }
    }
}

/// Every `Request` enum variant in `server/proto.rs` must be named as
/// `Request::Variant` in at least one *other* file — the dispatch
/// match, a handler, or a test pinning the behaviour.
fn r8_proto_variants(files: &[ParsedSource], out: &mut Vec<Finding>) {
    let mut handled: BTreeSet<String> = BTreeSet::new();
    for f in files {
        if f.rel.ends_with("server/proto.rs") {
            continue;
        }
        let toks = &f.toks;
        for i in 0..toks.len() {
            if toks[i].is_ident("Request")
                && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
                && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
            {
                if let Some(v) = ident_at(toks, i + 3) {
                    handled.insert(v.to_string());
                }
            }
        }
    }
    for f in files {
        if !f.rel.ends_with("server/proto.rs") {
            continue;
        }
        for (v, line) in enum_variants(&f.toks, "Request") {
            if !handled.contains(&v) {
                out.push(Finding {
                    rule: RuleId::R8,
                    file: f.rel.clone(),
                    line,
                    message: format!(
                        "protocol variant `Request::{v}` has no handler — nothing \
                         outside proto.rs names it, so requests of this kind fall \
                         through the dispatch match"
                    ),
                });
            }
        }
    }
}

/// Variant names (with lines) of `enum <name>` in a token stream.
fn enum_variants(toks: &[Token], name: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].is_ident("enum") && toks.get(i + 1).is_some_and(|t| t.is_ident(name))) {
            i += 1;
            continue;
        }
        // scan to the body's opening brace
        let mut j = i + 2;
        while toks.get(j).is_some_and(|t| !t.is_punct('{')) {
            j += 1;
        }
        let Some(end) = item_end(toks, j) else { return out };
        let mut expect_variant = true;
        let mut k = j + 1;
        while k + 1 < end {
            let t = &toks[k];
            if t.is_punct('#') {
                match skip_attr(toks, k) {
                    Some(n) => k = n,
                    None => break,
                }
                continue;
            }
            if t.is_punct('(') {
                match skip_parens(toks, k) {
                    Some(n) => k = n,
                    None => break,
                }
                continue;
            }
            if t.is_punct('{') {
                match item_end(toks, k) {
                    Some(n) => k = n,
                    None => break,
                }
                continue;
            }
            if t.is_punct(',') {
                expect_variant = true;
                k += 1;
                continue;
            }
            if expect_variant {
                if let Tok::Ident(v) = &t.tok {
                    out.push((v.clone(), t.line));
                    expect_variant = false;
                }
            }
            k += 1;
        }
        return out;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find(rel: &str, src: &str) -> Vec<(RuleId, u32)> {
        lint_source(rel, src).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "\
use std::collections::HashMap;\n\
#[cfg(test)]\n\
mod tests {\n\
    use std::collections::HashMap;\n\
    fn f() { x.unwrap(); }\n\
}\n";
        let hits = find("rust/src/dse/x.rs", src);
        assert_eq!(hits, vec![(RuleId::R1, 1)], "only the non-test HashMap flags");
    }

    #[test]
    fn r2_does_not_flag_temporary_guards_or_dropped_guards() {
        let clean = "\
fn a(m: &Mutex<u32>) -> u32 { *m.lock().unwrap() }\n\
fn b(m: &Mutex<Vec<u8>>, w: &mut TcpStream) {\n\
    let data = m.lock().unwrap().clone();\n\
    let g = m.lock().unwrap();\n\
    drop(g);\n\
    w.write_all(&data).ok();\n\
}\n";
        assert!(find("rust/src/util/x.rs", clean).iter().all(|(r, _)| *r != RuleId::R2));
    }

    #[test]
    fn r3_ignores_non_deprecated_sweep_infrastructure() {
        let src = "\
use crate::sweep::parallel_map;\n\
fn f() { let t = crate::sweep::default_threads(); parallel_map(&v, t, |x| x); }\n";
        assert!(find("rust/src/engine/x.rs", src).is_empty());
    }

    #[test]
    fn r4_skips_unwrap_or_variants() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0).max(x.unwrap_or_else(|| 1)) }\n";
        assert!(find("rust/src/util/x.rs", src).is_empty());
    }

    fn interp(files: &[(&str, &str)]) -> Vec<Finding> {
        let sources: Vec<(String, String)> =
            files.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect();
        lint_interprocedural(&sources)
    }

    #[test]
    fn r6_cross_function_double_lock_that_r2_cannot_see() {
        let src = "\
pub struct Shared { inner: Mutex<u32> }\n\
impl Shared {\n\
    fn helper(&self) -> u32 { *self.inner.lock().unwrap() }\n\
    fn outer(&self) -> u32 {\n\
        let g = self.inner.lock().unwrap();\n\
        *g + self.helper()\n\
    }\n\
}\n";
        // R2's same-function scan sees no violation in `outer`...
        assert!(lint_source("rust/src/a.rs", src)
            .iter()
            .all(|f| f.rule != RuleId::R2));
        // ...but the call graph does: the guard is held across a callee
        // that re-acquires the same mutex.
        let hits = interp(&[("rust/src/a.rs", src)]);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!((hits[0].rule, hits[0].line), (RuleId::R6, 6));
        assert!(hits[0].message.contains("Shared.inner"), "{}", hits[0].message);
    }

    #[test]
    fn r6_guard_across_callee_that_does_io() {
        let files = [
            (
                "rust/src/net.rs",
                "pub(crate) fn push(w: &mut TcpStream, b: &[u8]) { w.write_all(b).ok(); }\n",
            ),
            (
                "rust/src/svc.rs",
                "\
use crate::net::push;\n\
fn tick(m: &Mutex<Vec<u8>>, w: &mut TcpStream) {\n\
    let g = m.lock().unwrap();\n\
    push(w, &g);\n\
}\n",
            ),
        ];
        let hits = interp(&files);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, RuleId::R6);
        assert_eq!((hits[0].file.as_str(), hits[0].line), ("rust/src/svc.rs", 4));
        assert!(hits[0].message.contains("performs I/O"), "{}", hits[0].message);
    }

    #[test]
    fn r6_two_file_lock_order_cycle() {
        let files = [
            (
                "rust/src/x.rs",
                "\
fn ab(a: &Mutex<u32>, b: &Mutex<u32>) {\n\
    let g = a.lock().unwrap();\n\
    let h = b.lock().unwrap();\n\
    drop(h);\n\
    drop(g);\n\
}\n",
            ),
            (
                "rust/src/y.rs",
                "\
fn ba(a: &Mutex<u32>, b: &Mutex<u32>) {\n\
    let g = b.lock().unwrap();\n\
    let h = a.lock().unwrap();\n\
    drop(h);\n\
    drop(g);\n\
}\n",
            ),
        ];
        let hits = interp(&files);
        let cycles: Vec<&Finding> = hits
            .iter()
            .filter(|f| f.message.contains("lock-order cycle"))
            .collect();
        assert_eq!(cycles.len(), 1, "{hits:?}");
        assert_eq!(cycles[0].rule, RuleId::R6);
        assert_eq!(cycles[0].file, "rust/src/x.rs", "anchored at the smallest site");
        assert!(cycles[0].message.contains("a -> b -> a"), "{}", cycles[0].message);
    }

    #[test]
    fn r7_flags_cross_timeline_arithmetic_and_sinks() {
        let src = "\
fn f(total_cycles: u64, elapsed: u64) -> u64 {\n\
    total_cycles + elapsed\n\
}\n\
fn g(reg: &Registry, drained_cycles: u64) {\n\
    reg.observe_seconds(\"t\", drained_cycles as f64);\n\
}\n\
fn clean(total_cycles: u64, fill_cycles: u64) -> u64 {\n\
    total_cycles + fill_cycles\n\
}\n";
        let hits = interp(&[("rust/src/obs/metrics2.rs", src)]);
        let locs: Vec<(RuleId, u32)> = hits.iter().map(|f| (f.rule, f.line)).collect();
        assert_eq!(locs, vec![(RuleId::R7, 2), (RuleId::R7, 5)], "{hits:?}");
    }

    #[test]
    fn r8_unhandled_proto_variant_and_dead_pub_fn() {
        let files = [
            (
                "rust/src/server/proto.rs",
                "\
pub enum Request {\n\
    Ping,\n\
    Run { id: u64 },\n\
    Orphan,\n\
}\n",
            ),
            (
                "rust/src/server/mod.rs",
                "\
fn dispatch(req: Request) {\n\
    match req {\n\
        Request::Ping => {}\n\
        Request::Run { id } => {}\n\
        _ => {}\n\
    }\n\
}\n",
            ),
            (
                "rust/src/util/extra.rs",
                "pub fn used() -> u32 { 1 }\npub fn dead() -> u32 { 2 }\n",
            ),
            (
                "rust/tests/t.rs",
                "fn t() { scale_sim::util::extra::used(); }\n",
            ),
        ];
        let hits = interp(&files);
        let r8: Vec<(&str, u32)> = hits
            .iter()
            .filter(|f| f.rule == RuleId::R8)
            .map(|f| (f.file.as_str(), f.line))
            .collect();
        assert!(r8.contains(&("rust/src/server/proto.rs", 4)), "{hits:?}");
        assert!(r8.contains(&("rust/src/util/extra.rs", 2)), "{hits:?}");
        assert!(
            !r8.contains(&("rust/src/util/extra.rs", 1)),
            "test-reached fn is live: {hits:?}"
        );
        assert_eq!(r8.len(), 2, "{hits:?}");
    }

    #[test]
    fn classification_matrix() {
        assert_eq!(classify("rust/src/engine/mod.rs"), FileClass::Lib);
        assert_eq!(classify("rust/src/sweep/mod.rs"), FileClass::Shim);
        assert_eq!(classify("rust/src/config/topology.rs"), FileClass::Shim);
        assert_eq!(classify("rust/src/config/cfg.rs"), FileClass::Lib);
        assert_eq!(classify("rust/src/main.rs"), FileClass::Main);
        assert_eq!(classify("rust/tests/golden.rs"), FileClass::Test);
        assert_eq!(classify("rust/benches/perf_hotpath.rs"), FileClass::Bench);
    }
}
