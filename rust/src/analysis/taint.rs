//! Unit classification for R7, the two-timeline taint rule.
//!
//! The simulator lives on two clocks at once: the **simulated** clock
//! (cycles, the paper's unit of account) and the **wall** clock (how
//! long the simulator itself takes). PR 7's observability work made
//! mixing them an easy mistake — a cycle count fed into a wall-time
//! histogram renders a dashboard that is confidently wrong. R7 flags
//! arithmetic and metric sinks that mix the two (or either with raw
//! byte counts, the third unit family in bandwidth math).
//!
//! Classification is by **name provenance** only: an identifier's
//! substrings decide its class. That is deliberately shallow — it
//! needs no type information, works on the token stream, and matches
//! how this codebase actually names things (`cycles`, `total_cycles`,
//! `elapsed`, `wall_secs`, `bytes_read`). Names that hit two families
//! (`bytes_per_cycle`) are rates, not raw quantities, and classify as
//! nothing.

/// The three unit families R7 keeps apart.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitClass {
    /// Simulated time: cycle counts.
    Cycles,
    /// Wall-clock time: seconds, milliseconds, latencies.
    Wall,
    /// Raw byte counts.
    Bytes,
}

impl UnitClass {
    /// Human-readable family name for findings.
    pub fn name(self) -> &'static str {
        match self {
            UnitClass::Cycles => "cycle",
            UnitClass::Wall => "wall-time",
            UnitClass::Bytes => "byte",
        }
    }
}

/// Substrings marking an identifier as wall-clock-valued.
const WALL_CONTAINS: [&str; 5] = ["wall", "elapsed", "seconds", "secs", "latency"];
/// Unit-suffix spellings of wall-clock durations.
const WALL_SUFFIX: [&str; 5] = ["_ms", "_us", "_micros", "_millis", "_sec"];
const WALL_PREFIX: [&str; 2] = ["ms_", "us_"];

/// Classify an identifier by name, or `None` if it names no unit
/// family (or more than one — a rate or conversion, which legitimately
/// spans timelines).
pub fn classify_ident(name: &str) -> Option<UnitClass> {
    let lower = name.to_ascii_lowercase();
    let mut hits: Vec<UnitClass> = Vec::new();
    if lower.contains("cycle") {
        hits.push(UnitClass::Cycles);
    }
    let wall = WALL_CONTAINS.iter().any(|w| lower.contains(w))
        || WALL_SUFFIX.iter().any(|s| lower.ends_with(s))
        || WALL_PREFIX.iter().any(|p| lower.starts_with(p));
    if wall {
        hits.push(UnitClass::Wall);
    }
    if lower.contains("byte") {
        hits.push(UnitClass::Bytes);
    }
    match hits.as_slice() {
        [one] => Some(*one),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_names_classify_as_cycles() {
        for n in ["cycles", "total_cycles", "CycleCount", "fill_cycles"] {
            assert_eq!(classify_ident(n), Some(UnitClass::Cycles), "{n}");
        }
    }

    #[test]
    fn wall_names_classify_as_wall() {
        for n in ["elapsed", "wall_secs", "latency", "simulate_seconds", "dur_ms", "t_us"] {
            assert_eq!(classify_ident(n), Some(UnitClass::Wall), "{n}");
        }
    }

    #[test]
    fn byte_names_classify_as_bytes() {
        for n in ["bytes_read", "sram_bytes", "total_bytes"] {
            assert_eq!(classify_ident(n), Some(UnitClass::Bytes), "{n}");
        }
    }

    #[test]
    fn rates_and_plain_names_classify_as_nothing() {
        for n in ["bytes_per_cycle", "cycles_per_sec", "utilization", "layer", "x", "mask"] {
            assert_eq!(classify_ident(n), None, "{n}");
        }
    }
}
