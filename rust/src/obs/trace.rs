//! Simulated-time spans: the engine's cycle breakdowns as a hierarchical
//! timeline, exported as Chrome trace-event JSON (Perfetto-loadable).
//!
//! Spans are built *post hoc* from reports — the analytical backends
//! already know every fold's exact phase decomposition, so nothing on the
//! simulation hot path is instrumented. Per layer the span tree is:
//!
//! ```text
//! layer <name>                               (cat "layer")
//! ├─ fold x<n> <r>x<c>                       (cat "fold", one per distinct
//! │  ├─ fill    ─ array fill / operand pin    fold shape, aggregated over
//! │  ├─ stream  ─ moving-operand stream       its n occurrences)
//! │  └─ drain   ─ column reduction + drain
//! ├─ ...                                     (≤ 4 distinct shapes)
//! └─ stall                                   (cat "stall", only when a
//!                                             DRAM bandwidth is modeled)
//! ```
//!
//! Phase durations come from the same closed forms the dataflows use
//! (per-fold `fill + stream + drain == fold_cycles` by construction — see
//! [`fold_phases`]), so a layer's span total equals its
//! [`LayerReport`](crate::sim::LayerReport) `timing.cycles` **exactly**;
//! the obs test suite pins that identity across dataflows and shapes.
//!
//! Timestamps are cycles. Chrome's `ts`/`dur` unit is microseconds; we
//! write cycles into those fields directly, so Perfetto's "us" readouts
//! are really cycles — `docs/OBSERVABILITY.md` documents the convention.
//! Multi-array runs place each node on its own `pid` track, so per-node
//! skew (remainder shares, idle nodes) is visible at a glance.

use std::path::Path;

use crate::arch::LayerShape;
use crate::dataflow::{self, Dataflow};
use crate::engine::MultiWorkloadReport;
use crate::sim::{LayerReport, WorkloadReport};
use crate::util::json::Json;

/// One complete ("ph":"X") trace event, stamped in cycles.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpan {
    pub name: String,
    /// Category: `layer` | `fold` | `phase` | `stall`.
    pub cat: &'static str,
    /// Process track — node index under multi-array runs, 0 otherwise.
    pub pid: u64,
    /// Start cycle.
    pub ts: u64,
    /// Duration in cycles.
    pub dur: u64,
    /// Extra `args` fields surfaced in the trace viewer.
    pub args: Vec<(&'static str, Json)>,
}

/// An in-memory trace: spans plus per-pid track names.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<TraceSpan>,
    /// `(pid, display name)` — emitted as `process_name` metadata events.
    names: Vec<(u64, String)>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn push(&mut self, span: TraceSpan) {
        self.spans.push(span);
    }

    /// Name the `pid` track (e.g. `node 3`) in the viewer.
    pub fn name_process(&mut self, pid: u64, name: impl Into<String>) {
        self.names.push((pid, name.into()));
    }

    /// Total span cycles per category (the profile table's input).
    pub fn category_total(&self, cat: &str) -> u64 {
        self.spans.iter().filter(|s| s.cat == cat).map(|s| s.dur).sum()
    }

    /// The Chrome trace-event document: `{"traceEvents":[...]}` with one
    /// `M` (metadata) event per named track and one `X` (complete) event
    /// per span, in insertion order.
    pub fn to_json(&self) -> Json {
        let mut events = Vec::with_capacity(self.names.len() + self.spans.len());
        for (pid, name) in &self.names {
            events.push(Json::obj(vec![
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::u64(*pid)),
                ("tid", Json::u64(0)),
                ("args", Json::obj(vec![("name", Json::str(name.clone()))])),
            ]));
        }
        for s in &self.spans {
            let mut args = vec![("cat_cycles", Json::u64(s.dur))];
            args.extend(s.args.iter().cloned());
            events.push(Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("cat", Json::str(s.cat)),
                ("ph", Json::str("X")),
                ("ts", Json::u64(s.ts)),
                ("dur", Json::u64(s.dur)),
                ("pid", Json::u64(s.pid)),
                ("tid", Json::u64(0)),
                ("args", Json::Obj(args.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            // cycles ride in the microsecond fields; see module docs
            ("displayTimeUnit", Json::str("ms")),
        ])
    }

    /// Write the trace document (single line + trailing newline).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// Per-fold phase durations for one fold shape (`r x c` PEs mapped).
///
/// `fill + stream + drain` equals the dataflow's per-fold closed form
/// exactly:
///
/// | df | fill  | stream | drain   | total          |
/// |----|-------|--------|---------|----------------|
/// | OS | `r-1` | `K`    | `r+c-1` | `2r+c+K-2`     |
/// | WS | `r`   | `Npx`  | `r+c-1` | `2r+c+Npx-1`   |
/// | IS | `r`   | `Nf`   | `r+c-1` | `2r+c+Nf-1`    |
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldPhases {
    /// Array fill: input skew (OS) or stationary-operand pin (WS/IS).
    pub fill: u64,
    /// Moving-operand stream through the pinned array.
    pub stream: u64,
    /// Column reduction skew + result drain.
    pub drain: u64,
}

impl FoldPhases {
    pub fn total(&self) -> u64 {
        self.fill + self.stream + self.drain
    }
}

/// Phase decomposition of one fold mapping `r x c` PEs of `layer` under
/// `df` (see the [`FoldPhases`] table).
pub fn fold_phases(df: Dataflow, layer: &LayerShape, r: u64, c: u64) -> FoldPhases {
    let drain = r + c - 1;
    match df {
        Dataflow::Os => FoldPhases { fill: r - 1, stream: layer.window(), drain },
        Dataflow::Ws => FoldPhases { fill: r, stream: layer.npx(), drain },
        Dataflow::Is => FoldPhases { fill: r, stream: layer.num_filters, drain },
    }
}

/// The fold grid `(total_r, total_c)` a dataflow time-multiplexes over
/// `rows x cols` PEs (OS: pixels x filters; WS: window x filters;
/// IS: window x pixels).
pub fn fold_grid(df: Dataflow, layer: &LayerShape) -> (u64, u64) {
    match df {
        Dataflow::Os => (layer.npx(), layer.num_filters),
        Dataflow::Ws => (layer.window(), layer.num_filters),
        Dataflow::Is => (layer.window(), layer.npx()),
    }
}

/// Aggregate fill/stream/drain cycles of a whole layer (every fold,
/// multiplicity-weighted) — the profile table's per-layer row, with
/// `total() == Timing.cycles` exactly.
pub fn phase_totals(df: Dataflow, rows: u64, cols: u64, layer: &LayerShape) -> FoldPhases {
    let (total_r, total_c) = fold_grid(df, layer);
    let mut agg = FoldPhases { fill: 0, stream: 0, drain: 0 };
    dataflow::for_fold_shapes(total_r, rows, total_c, cols, |n, r, c| {
        let p = fold_phases(df, layer, r, c);
        agg.fill += n * p.fill;
        agg.stream += n * p.stream;
        agg.drain += n * p.drain;
    });
    agg
}

/// Append the span tree of one simulated layer starting at cycle
/// `start` on track `pid`; returns the cursor past the layer (compute +
/// stall). The fold grid is walked in the dataflows' own shape order
/// (≤ 4 distinct shapes), each shape contributing one aggregated
/// `fold x<n>` span with fill/stream/drain children.
pub fn layer_spans(
    trace: &mut Trace,
    pid: u64,
    start: u64,
    df: Dataflow,
    rows: u64,
    cols: u64,
    report: &LayerReport,
    stall_cycles: u64,
) -> u64 {
    let layer = &report.layer;
    let compute = report.timing.cycles;
    trace.push(TraceSpan {
        name: layer.name.clone(),
        cat: "layer",
        pid,
        ts: start,
        dur: compute + stall_cycles,
        args: vec![
            ("cycles", Json::u64(compute)),
            ("stall_cycles", Json::u64(stall_cycles)),
            ("utilization", Json::f64(report.timing.utilization)),
            ("dataflow", Json::str(df.name())),
        ],
    });
    let (total_r, total_c) = fold_grid(df, layer);
    let mut shapes = Vec::new();
    dataflow::for_fold_shapes(total_r, rows, total_c, cols, |n, r, c| shapes.push((n, r, c)));
    let mut cursor = start;
    for (n, r, c) in shapes {
        let p = fold_phases(df, layer, r, c);
        let dur = n * p.total();
        trace.push(TraceSpan {
            name: format!("fold x{n} {r}x{c}"),
            cat: "fold",
            pid,
            ts: cursor,
            dur,
            args: vec![("folds", Json::u64(n))],
        });
        for (name, phase_dur) in
            [("fill", n * p.fill), ("stream", n * p.stream), ("drain", n * p.drain)]
        {
            trace.push(TraceSpan {
                name: name.to_string(),
                cat: "phase",
                pid,
                ts: cursor,
                dur: phase_dur,
                args: Vec::new(),
            });
            cursor += phase_dur;
        }
    }
    debug_assert_eq!(cursor - start, compute, "span phases must tile the layer exactly");
    if stall_cycles > 0 {
        trace.push(TraceSpan {
            name: "stall".to_string(),
            cat: "stall",
            pid,
            ts: start + compute,
            dur: stall_cycles,
            args: Vec::new(),
        });
    }
    start + compute + stall_cycles
}

/// Span timeline of a whole single-array workload: layers laid
/// back-to-back from cycle 0 on track `pid` 0. `stalls`, when present,
/// carries one DRAM-stall cycle count per layer (same order).
pub fn workload_trace(
    df: Dataflow,
    rows: u64,
    cols: u64,
    report: &WorkloadReport,
    stalls: Option<&[u64]>,
) -> Trace {
    let mut t = Trace::new();
    t.name_process(0, format!("{} ({} {rows}x{cols})", report.workload, df.name()));
    let mut cursor = 0u64;
    for (i, l) in report.layers.iter().enumerate() {
        let stall = stalls.and_then(|s| s.get(i).copied()).unwrap_or(0);
        cursor = layer_spans(&mut t, 0, cursor, df, rows, cols, l, stall);
    }
    t
}

/// Span timeline of a multi-array run: one `pid` track per node, nodes
/// running each layer in parallel (layers still serialize — each starts
/// at the previous layer's slowest-node finish, stalls included).
/// Remainder shares land on the last used node; idle nodes show gaps.
pub fn multi_trace(df: Dataflow, report: &MultiWorkloadReport) -> Trace {
    let (rows, cols) = report.multi.node_shape;
    let mut t = Trace::new();
    let max_used = report.layers.iter().map(|l| l.used_nodes).max().unwrap_or(0);
    for pid in 0..max_used {
        t.name_process(pid, format!("node {pid} ({} {rows}x{cols})", df.name()));
    }
    let mut cursor = 0u64;
    for l in &report.layers {
        for pid in 0..l.node_count {
            layer_spans(&mut t, pid, cursor, df, rows, cols, &l.node_report, 0);
        }
        if let Some(r) = &l.remainder {
            layer_spans(&mut t, l.node_count, cursor, df, rows, cols, r, 0);
        }
        if l.stall_cycles > 0 {
            // shared-DRAM stall of the slowest node bounds the layer
            t.push(TraceSpan {
                name: "stall".to_string(),
                cat: "stall",
                pid: 0,
                ts: cursor + l.cycles,
                dur: l.stall_cycles,
                args: Vec::new(),
            });
        }
        cursor += l.cycles + l.stall_cycles;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::engine::Engine;

    #[test]
    fn phases_tile_every_fold_shape_exactly() {
        let l = LayerShape::conv("c", 17, 17, 3, 3, 13, 37, 1);
        for df in Dataflow::ALL {
            for &(r, c) in &[(1u64, 1u64), (3, 5), (8, 8), (16, 2)] {
                let p = fold_phases(df, &l, r, c);
                let expect = match df {
                    Dataflow::Os => 2 * r + c + l.window() - 2,
                    Dataflow::Ws => 2 * r + c + l.npx() - 1,
                    Dataflow::Is => 2 * r + c + l.num_filters - 1,
                };
                assert_eq!(p.total(), expect, "{df} {r}x{c}");
            }
        }
    }

    #[test]
    fn layer_span_totals_equal_report_cycles() {
        let cfg = config::paper_default();
        let e = Engine::new(cfg.clone());
        let l = LayerShape::conv("c", 31, 31, 3, 3, 30, 70, 1);
        for df in Dataflow::ALL {
            let cfg = crate::config::ArchConfig { dataflow: df, ..cfg.clone() };
            let report = e.run_layer_with(&cfg, &l);
            let mut t = Trace::new();
            let end = layer_spans(&mut t, 0, 0, df, cfg.array_h, cfg.array_w, &report, 0);
            assert_eq!(end, report.timing.cycles, "{df}");
            let agg = phase_totals(df, cfg.array_h, cfg.array_w, &l);
            assert_eq!(agg.total(), report.timing.cycles, "{df}");
        }
    }

    #[test]
    fn trace_json_parses_and_round_trips() {
        let mut t = Trace::new();
        t.name_process(0, "p");
        t.push(TraceSpan {
            name: "x".into(),
            cat: "layer",
            pid: 0,
            ts: 0,
            dur: 10,
            args: vec![("cycles", Json::u64(10))],
        });
        let text = t.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.to_string(), text);
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 2);
    }
}
