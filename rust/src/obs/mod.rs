//! Two-timeline observability (ROADMAP items 2 & 5's instrumentation
//! substrate).
//!
//! The paper's core results (Figs 5–10) are *cycle breakdowns* — compute
//! vs drain vs stall, per dataflow, per node — yet end-of-run aggregates
//! flatten all of it. This module keeps the two timelines separate and
//! first-class:
//!
//! * [`trace`] — **simulated time**: hierarchical spans stamped in
//!   cycles (layer → fold → fill/stream/drain, plus stall and per-node
//!   tracks), built post hoc from engine reports and exported as Chrome
//!   trace-event JSON (`--trace-out`, Perfetto-loadable). Span totals
//!   equal the reports' cycle counts exactly — the timeline *is* the
//!   paper's breakdown, inspectable.
//! * [`metrics`] — **host wall time**: a `BTreeMap`-keyed
//!   counters/gauges/histograms registry with Prometheus text
//!   exposition. Deterministic series (cache, queue, workers, dse
//!   progress) render byte-stably; wall-clock latency histograms are an
//!   opt-in second class, fed only through the sanctioned
//!   [`crate::util::bench`] clock (lint R1).
//!
//! Surfaces: `scale-sim profile` (span-tree table + `BENCH_profile.json`
//! + `--trace-out`/`--metrics-out`), the serve protocol's `metrics`
//! request (`scale-sim client metrics`), and `--trace-out` on
//! run/sweep/dse. See `docs/OBSERVABILITY.md` for the span taxonomy and
//! metric name inventory.

pub mod metrics;
pub mod trace;

pub use metrics::Registry;
pub use trace::{FoldPhases, Trace, TraceSpan};
