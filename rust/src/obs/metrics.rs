//! Host wall-time metrics: a counters/gauges/histograms registry with
//! deterministic Prometheus text exposition.
//!
//! Two classes of series live side by side (the *two-timeline model*,
//! `docs/OBSERVABILITY.md`):
//!
//! * **deterministic** — counters and gauges whose values derive from
//!   simulated quantities or event counts (cache hits, queue depth,
//!   dse points). Given the same inputs they are bit-identical across
//!   processes, so [`Registry::render`]`(false)` — the default server
//!   `metrics` response and the `--metrics-out` snapshot — is
//!   byte-stable and two-process-diffable.
//! * **wall-clock** — latency histograms observed through the sanctioned
//!   [`crate::util::bench`] timing path (lint R1 allows no other clock).
//!   Only `render(true)` includes them.
//!
//! Keys are a `BTreeMap`, so exposition order is lexicographic and
//! stable — never hash order. Labeled series embed their label set in
//! the key (`scale_sim_simulate_seconds{backend="analytical"}`); the
//! metric *family* is the key up to the `{`, and `# HELP`/`# TYPE`
//! headers are emitted once per family.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::engine::{MemoStats, WarmStats};

/// Histogram bucket upper bounds in seconds (per-layer simulate
/// latencies span ~1µs analytical to ~100ms RTL). `+Inf` is implicit.
pub const LATENCY_BUCKETS: [f64; 8] =
    [0.000001, 0.00001, 0.0001, 0.001, 0.01, 0.1, 1.0, 10.0];

enum Metric {
    Counter { help: &'static str, value: u64 },
    Gauge { help: &'static str, value: f64 },
    /// Wall-clock class counter: tallies scheduling artifacts — work
    /// steals, stripe-lock contention, peer failovers — that legitimately
    /// vary run to run. Rendered (as a plain Prometheus counter) only
    /// with `include_wall`, so `render(false)` stays byte-stable.
    WallCounter { help: &'static str, value: u64 },
    /// Wall-clock class: one cumulative count per [`LATENCY_BUCKETS`]
    /// bound plus the implicit `+Inf`.
    Histogram { help: &'static str, buckets: [u64; LATENCY_BUCKETS.len()], sum: f64, count: u64 },
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter { .. } | Metric::WallCounter { .. } => "counter",
            Metric::Gauge { .. } => "gauge",
            Metric::Histogram { .. } => "histogram",
        }
    }

    fn help(&self) -> &'static str {
        match self {
            Metric::Counter { help, .. }
            | Metric::Gauge { help, .. }
            | Metric::WallCounter { help, .. }
            | Metric::Histogram { help, .. } => help,
        }
    }

    /// True for series excluded from the deterministic exposition.
    fn is_wall(&self) -> bool {
        matches!(self, Metric::WallCounter { .. } | Metric::Histogram { .. })
    }
}

/// A metrics registry: `BTreeMap`-keyed for deterministic exposition.
/// [`global`] returns the process-wide instance; scoped instances (e.g.
/// the server's per-[`ServerStats`](crate::server::ServerStats)
/// exposition) are built fresh per render.
pub struct Registry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub const fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Poison-recovering lock: metrics must never take a worker down.
    fn table(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Monotonically increase a counter by `delta`.
    pub fn add_counter(&self, name: &str, help: &'static str, delta: u64) {
        let mut t = self.table();
        match t.get_mut(name) {
            Some(Metric::Counter { value, .. }) => *value += delta,
            _ => {
                t.insert(name.to_string(), Metric::Counter { help, value: delta });
            }
        }
    }

    /// Set a counter to an absolute value (for counters mirrored from a
    /// source atomic — the pull-model series).
    pub fn set_counter(&self, name: &str, help: &'static str, value: u64) {
        self.table().insert(name.to_string(), Metric::Counter { help, value });
    }

    pub fn set_gauge(&self, name: &str, help: &'static str, value: f64) {
        self.table().insert(name.to_string(), Metric::Gauge { help, value });
    }

    /// Monotonically increase a wall-class counter (scheduling
    /// artifacts; excluded from `render(false)`).
    pub fn add_wall_counter(&self, name: &str, help: &'static str, delta: u64) {
        let mut t = self.table();
        match t.get_mut(name) {
            Some(Metric::WallCounter { value, .. }) => *value += delta,
            _ => {
                t.insert(name.to_string(), Metric::WallCounter { help, value: delta });
            }
        }
    }

    /// Set a wall-class counter to an absolute value (mirror of a
    /// source atomic, e.g. the memo cache's stripe-contention tally).
    pub fn set_wall_counter(&self, name: &str, help: &'static str, value: u64) {
        self.table().insert(name.to_string(), Metric::WallCounter { help, value });
    }

    /// Record one wall-clock observation into a latency histogram.
    pub fn observe_seconds(&self, name: &str, help: &'static str, secs: f64) {
        let mut t = self.table();
        let entry = t.entry(name.to_string()).or_insert(Metric::Histogram {
            help,
            buckets: [0; LATENCY_BUCKETS.len()],
            sum: 0.0,
            count: 0,
        });
        if let Metric::Histogram { buckets, sum, count, .. } = entry {
            for (i, bound) in LATENCY_BUCKETS.iter().enumerate() {
                if secs <= *bound {
                    buckets[i] += 1;
                }
            }
            *sum += secs;
            *count += 1;
        }
    }

    /// Drop every series (test isolation).
    pub fn reset(&self) {
        self.table().clear();
    }

    /// Prometheus text exposition. `include_wall: false` renders only
    /// the deterministic class (counters + gauges); `true` adds the
    /// wall-clock histograms. Output ends with a newline; families are
    /// in lexicographic key order with one `# HELP`/`# TYPE` pair each.
    pub fn render(&self, include_wall: bool) -> String {
        self.render_filtered(|m| include_wall || !m.is_wall())
    }

    /// The complement of `render(false)`: wall-class series only. The
    /// serve `metrics` event appends this section after the
    /// deterministic snapshot, so family names never repeat within one
    /// exposition.
    pub fn render_wall_only(&self) -> String {
        self.render_filtered(Metric::is_wall)
    }

    fn render_filtered(&self, keep: impl Fn(&Metric) -> bool) -> String {
        let t = self.table();
        let mut out = String::new();
        let mut last_family = String::new();
        for (key, m) in t.iter() {
            if !keep(m) {
                continue;
            }
            let (family, labels) = split_labels(key);
            if family != last_family {
                out.push_str(&format!("# HELP {family} {}\n", m.help()));
                out.push_str(&format!("# TYPE {family} {}\n", m.type_name()));
                last_family = family.to_string();
            }
            match m {
                Metric::Counter { value, .. } | Metric::WallCounter { value, .. } => {
                    out.push_str(&format!("{key} {value}\n"))
                }
                Metric::Gauge { value, .. } => out.push_str(&format!("{key} {value}\n")),
                Metric::Histogram { buckets, sum, count, .. } => {
                    for (i, bound) in LATENCY_BUCKETS.iter().enumerate() {
                        out.push_str(&format!(
                            "{family}_bucket{{{}le=\"{bound}\"}} {}\n",
                            label_prefix(labels),
                            buckets[i]
                        ));
                    }
                    out.push_str(&format!(
                        "{family}_bucket{{{}le=\"+Inf\"}} {count}\n",
                        label_prefix(labels)
                    ));
                    out.push_str(&format!("{family}_sum{labels_suffix} {sum}\n",
                        labels_suffix = brace(labels)));
                    out.push_str(&format!("{family}_count{labels_suffix} {count}\n",
                        labels_suffix = brace(labels)));
                }
            }
        }
        out
    }
}

/// Split `family{label="x"}` into `(family, inner labels)`; labels are
/// `""` for unlabeled keys.
fn split_labels(key: &str) -> (&str, &str) {
    match key.find('{') {
        Some(i) => (&key[..i], key[i + 1..].trim_end_matches('}')),
        None => (key, ""),
    }
}

/// `labels` followed by a comma, or empty — for joining with `le=`.
fn label_prefix(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{labels},")
    }
}

/// `{labels}` or empty — for `_sum`/`_count` sample names.
fn brace(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

/// The process-wide registry: engine simulate-latency histograms and
/// dse progress counters land here.
pub fn global() -> &'static Registry {
    static GLOBAL: Registry = Registry::new();
    &GLOBAL
}

/// Mirror the engine's memo-cache counters into `reg` (pull model: the
/// cache keeps its own atomics; exposition snapshots them).
pub fn record_cache(reg: &Registry, memo: &MemoStats, warm: &WarmStats, entries: u64) {
    reg.set_counter(
        "scale_sim_cache_misses_total",
        "Layer simulations actually computed (memo-cache misses)",
        memo.layer_sims,
    );
    reg.set_counter(
        "scale_sim_cache_hits_total",
        "Layer reports served from the memo cache",
        memo.cache_hits,
    );
    reg.set_counter(
        "scale_sim_cache_inflight_waits_total",
        "Threads that blocked on another thread's in-flight computation of the same key",
        memo.inflight_waits,
    );
    reg.set_counter(
        "scale_sim_cache_warm_hits_total",
        "Hits served by entries prewarmed from a persistent store",
        warm.hits,
    );
    reg.set_gauge(
        "scale_sim_cache_entries",
        "Distinct (config, layer-shape) entries currently cached",
        entries as f64,
    );
    reg.set_gauge(
        "scale_sim_cache_warm_entries",
        "Cache entries preloaded from a persistent store",
        warm.entries as f64,
    );
}

/// Render the server's `metrics` response from one [`ServerStats`]
/// snapshot: cache + queue + worker series in a *fresh* registry (never
/// the process-global one, so concurrent in-process servers — as in the
/// loopback test suites — cannot cross-contaminate each other's
/// scrapes). Deterministic class only: two scrapes of an idle server
/// are byte-identical.
pub fn server_exposition(s: &crate::server::proto::ServerStats) -> String {
    let reg = Registry::new();
    record_cache(&reg, &s.memo, &s.warm, s.cache_entries as u64);
    reg.set_gauge(
        "scale_sim_queue_depth",
        "Jobs waiting in the bounded submission queue",
        s.queue_depth as f64,
    );
    reg.set_gauge(
        "scale_sim_queue_inflight",
        "Jobs accepted but not yet finished (queued + executing)",
        s.in_flight as f64,
    );
    reg.set_counter(
        "scale_sim_jobs_submitted_total",
        "Jobs accepted into the queue since server start",
        s.submitted,
    );
    reg.set_counter(
        "scale_sim_jobs_completed_total",
        "Jobs that finished normally",
        s.completed,
    );
    reg.set_counter(
        "scale_sim_jobs_failed_total",
        "Jobs that ended abnormally (worker panic)",
        s.failed,
    );
    reg.set_gauge("scale_sim_workers", "Worker threads serving the queue", s.workers as f64);
    reg.set_gauge(
        "scale_sim_workers_busy",
        "Worker threads currently executing a job",
        s.workers_busy as f64,
    );
    reg.render(false)
}

/// Observe one per-layer simulate latency under its backend label (the
/// engine calls this on every memo-cache miss, timed through
/// [`crate::util::bench::time`]).
pub fn observe_simulate_latency(backend: &'static str, elapsed: Duration) {
    global().observe_seconds(
        &format!("scale_sim_simulate_seconds{{backend=\"{backend}\"}}"),
        "Wall-clock latency of one per-layer backend simulation",
        elapsed.as_secs_f64(),
    );
}

/// Count one evaluated dse campaign point (shard progress).
pub fn count_dse_point() {
    global().add_counter(
        "scale_sim_dse_points_total",
        "DSE campaign points evaluated by this process",
        1,
    );
}

/// Count one layer simulated through the route-aware fabric path (the
/// opt-in cycle-accurate interconnect model).
pub fn count_fabric_layer() {
    global().add_counter(
        "scale_sim_fabric_layers_total",
        "Layers simulated through the route-aware fabric contention model",
        1,
    );
}

/// Count work-stealing pool steals (wall class: which worker steals
/// what is a scheduling artifact). Flushed once per `parallel_map`
/// invocation rather than per steal to keep the registry off the hot
/// path.
pub fn count_steals(n: u64) {
    global().add_wall_counter(
        "scale_sim_steals_total",
        "Tasks taken from another worker's deque by the work-stealing pool",
        n,
    );
}

/// Mirror the memo cache's cumulative stripe-lock contention tally
/// (wall class — it depends on thread interleaving, never on inputs).
pub fn record_stripe_contention(total: u64) {
    global().set_wall_counter(
        "scale_sim_cache_stripe_contention_total",
        "Memo-cache stripe locks found held by another thread",
        total,
    );
}

/// Count one layer report fetched from a federated serve peer.
pub fn count_peer_fetch() {
    global().add_wall_counter(
        "scale_sim_peer_fetches_total",
        "Layer reports served by a federated peer instance",
        1,
    );
}

/// Count one failover to local compute after a peer fetch failed.
pub fn count_peer_failover() {
    global().add_wall_counter(
        "scale_sim_peer_failovers_total",
        "Peer fetches that failed and fell back to local compute",
        1,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_sorted_grouped_and_stable() {
        let reg = Registry::new();
        reg.set_gauge("b_gauge", "second", 2.5);
        reg.set_counter("a_counter", "first", 7);
        let text = reg.render(false);
        let a = text.find("a_counter 7").unwrap();
        let b = text.find("b_gauge 2.5").unwrap();
        assert!(a < b, "{text}");
        assert!(text.contains("# HELP a_counter first"), "{text}");
        assert!(text.contains("# TYPE a_counter counter"), "{text}");
        assert!(text.contains("# TYPE b_gauge gauge"), "{text}");
        assert_eq!(text, reg.render(false), "render must be idempotent");
    }

    #[test]
    fn histograms_are_wall_class_only() {
        let reg = Registry::new();
        reg.set_counter("a_total", "det", 1);
        reg.observe_seconds("lat_seconds", "wall", 0.0005);
        reg.observe_seconds("lat_seconds", "wall", 2.0);
        let det = reg.render(false);
        assert!(!det.contains("lat_seconds"), "{det}");
        let wall = reg.render(true);
        assert!(wall.contains("lat_seconds_bucket{le=\"0.001\"} 1"), "{wall}");
        assert!(wall.contains("lat_seconds_bucket{le=\"+Inf\"} 2"), "{wall}");
        assert!(wall.contains("lat_seconds_count 2"), "{wall}");
        // bucket counts are cumulative (monotone)
        let mut last = 0u64;
        for b in LATENCY_BUCKETS {
            let needle = format!("lat_seconds_bucket{{le=\"{b}\"}} ");
            let line = wall.lines().find(|l| l.starts_with(&needle)).unwrap();
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{wall}");
            last = v;
        }
    }

    #[test]
    fn labeled_families_share_one_header() {
        let reg = Registry::new();
        reg.observe_seconds("sim_seconds{backend=\"analytical\"}", "h", 0.001);
        reg.observe_seconds("sim_seconds{backend=\"rtl\"}", "h", 0.1);
        let text = reg.render(true);
        assert_eq!(text.matches("# TYPE sim_seconds histogram").count(), 1, "{text}");
        assert!(text.contains("sim_seconds_bucket{backend=\"analytical\",le=\"0.001\"} 1"));
        assert!(text.contains("sim_seconds_sum{backend=\"rtl\"}"), "{text}");
    }

    #[test]
    fn cache_mirror_names_the_promised_series() {
        let reg = Registry::new();
        record_cache(
            &reg,
            &MemoStats { layer_sims: 3, cache_hits: 9, inflight_waits: 1 },
            &WarmStats { entries: 2, hits: 5 },
            4,
        );
        let text = reg.render(false);
        for needle in [
            "scale_sim_cache_misses_total 3",
            "scale_sim_cache_hits_total 9",
            "scale_sim_cache_inflight_waits_total 1",
            "scale_sim_cache_warm_hits_total 5",
            "scale_sim_cache_entries 4",
            "scale_sim_cache_warm_entries 2",
        ] {
            assert!(text.contains(needle), "missing {needle} in {text}");
        }
    }

    #[test]
    fn wall_counters_are_excluded_from_deterministic_render() {
        let reg = Registry::new();
        reg.set_counter("det_total", "deterministic", 4);
        reg.add_wall_counter("steals_total", "wall", 2);
        reg.add_wall_counter("steals_total", "wall", 3);
        let det = reg.render(false);
        assert!(!det.contains("steals_total"), "{det}");
        assert!(det.contains("det_total 4"), "{det}");
        let wall = reg.render(true);
        assert!(wall.contains("steals_total 5"), "{wall}");
        // still advertised as a plain Prometheus counter
        assert!(wall.contains("# TYPE steals_total counter"), "{wall}");
    }

    #[test]
    fn wall_counter_set_mirrors_an_absolute_total() {
        let reg = Registry::new();
        reg.set_wall_counter("contention_total", "wall mirror", 7);
        reg.set_wall_counter("contention_total", "wall mirror", 9);
        assert!(reg.render(true).contains("contention_total 9"));
        assert_eq!(reg.render(false), "");
    }

    #[test]
    fn counters_add_and_set() {
        let reg = Registry::new();
        reg.add_counter("c", "h", 2);
        reg.add_counter("c", "h", 3);
        assert!(reg.render(false).contains("c 5"));
        reg.set_counter("c", "h", 1);
        assert!(reg.render(false).contains("c 1"));
        reg.reset();
        assert_eq!(reg.render(true), "");
    }
}
