//! Criterion-style micro-bench timing for the `harness = false` bench
//! binaries (criterion itself is unavailable offline).
//!
//! Provides warmup, repeated measurement, and median/mean/min reporting in
//! a stable, grep-able one-line format:
//!
//! ```text
//! bench <name> ... median 1.234ms mean 1.240ms min 1.201ms (20 iters)
//! ```

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "bench {:<48} median {:>12?} mean {:>12?} min {:>12?} ({} iters)",
            self.name, self.median, self.mean, self.min, self.iters
        )
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time one closure, returning its value and the elapsed wall time —
/// THE sanctioned wall-clock entry point for instrumentation living
/// outside this module (lint R1 bans clock sources elsewhere; callers
/// route single-shot timings through here, e.g. the engine's simulate
/// latency histograms in [`crate::obs::metrics`]).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        median: samples[iters / 2],
        mean: total / iters as u32,
        min: samples[0],
        max: samples[iters - 1],
    };
    println!("{}", stats.line());
    stats
}

/// Write a flat JSON object of numeric benchmark fields (stable field
/// order, machine-greppable) — the `BENCH_*.json` perf-trajectory
/// artifacts, e.g. sweep wall-clock + memo-cache hit rate:
///
/// ```text
/// {"sweep_wall_ms": 41.72, "points": 105, "layer_sims": 855,
///  "cache_hits": 1125, "cache_hit_rate": 0.5682}
/// ```
pub fn write_json(path: &std::path::Path, fields: &[(&str, f64)]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    // f64 Display prints the shortest round-trip decimal ("105", "12.5",
    // "0.5682") — valid JSON for every finite value we emit.
    let body: Vec<String> =
        fields.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
    std::fs::write(path, format!("{{{}}}\n", body.join(", ")))
}

/// Linearly-interpolated percentile of `samples` (any order); `p` in
/// [0, 100]. Returns 0.0 on empty input — the serve bench's latency
/// p50/p99 reporter.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0).clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// phase lasts roughly `target`.
pub fn bench_auto<T>(name: &str, target: Duration, mut f: impl FnMut() -> T) -> BenchStats {
    let t0 = Instant::now();
    black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (target.as_nanos() / one.as_nanos()).clamp(3, 1000) as usize;
    bench(name, 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop_sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.line().contains("noop_sum"));
    }

    #[test]
    fn bench_auto_caps_iters() {
        let s = bench_auto("noop", Duration::from_millis(5), || 1u64 + 1);
        assert!(s.iters >= 3 && s.iters <= 1000);
    }

    #[test]
    fn percentile_interpolates_and_clamps() {
        let s = [40.0, 10.0, 20.0, 30.0]; // sorted: 10 20 30 40
        assert_eq!(percentile(&s, 0.0), 10.0);
        assert_eq!(percentile(&s, 100.0), 40.0);
        assert_eq!(percentile(&s, 50.0), 25.0);
        assert!((percentile(&s, 99.0) - 39.7).abs() < 1e-9);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
    }

    #[test]
    fn write_json_emits_flat_object() {
        let path = std::env::temp_dir()
            .join(format!("scale_sim_bench_{}", std::process::id()))
            .join("BENCH_test.json");
        write_json(&path, &[("wall_ms", 12.5), ("points", 105.0), ("hit_rate", 0.5682)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with('{') && text.trim_end().ends_with('}'), "{text}");
        assert!(text.contains("\"wall_ms\": 12.5"), "{text}");
        assert!(text.contains("\"points\": 105"), "{text}"); // integral -> int
        assert!(text.contains("\"hit_rate\": 0.5682"), "{text}");
        std::fs::remove_dir_all(path.parent().unwrap()).unwrap();
    }
}
