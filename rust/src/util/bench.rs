//! Criterion-style micro-bench timing for the `harness = false` bench
//! binaries (criterion itself is unavailable offline).
//!
//! Provides warmup, repeated measurement, and median/mean/min reporting in
//! a stable, grep-able one-line format:
//!
//! ```text
//! bench <name> ... median 1.234ms mean 1.240ms min 1.201ms (20 iters)
//! ```

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "bench {:<48} median {:>12?} mean {:>12?} min {:>12?} ({} iters)",
            self.name, self.median, self.mean, self.min, self.iters
        )
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        median: samples[iters / 2],
        mean: total / iters as u32,
        min: samples[0],
        max: samples[iters - 1],
    };
    println!("{}", stats.line());
    stats
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// phase lasts roughly `target`.
pub fn bench_auto<T>(name: &str, target: Duration, mut f: impl FnMut() -> T) -> BenchStats {
    let t0 = Instant::now();
    black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (target.as_nanos() / one.as_nanos()).clamp(3, 1000) as usize;
    bench(name, 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop_sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.line().contains("noop_sum"));
    }

    #[test]
    fn bench_auto_caps_iters() {
        let s = bench_auto("noop", Duration::from_millis(5), || 1u64 + 1);
        assert!(s.iters >= 3 && s.iters <= 1000);
    }
}
