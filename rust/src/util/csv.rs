//! Tiny csv reader/writer (the `csv` crate is unavailable offline).
//!
//! Handles the subset SCALE-Sim's file formats need: comma separation,
//! optional header row, whitespace trimming, `#` comment lines, and
//! trailing commas (the original SCALE-Sim topology files end rows with
//! one).

use std::io::Write;
use std::path::Path;

/// Parse csv text into trimmed string cells, skipping blank/comment lines.
pub fn parse(text: &str) -> Vec<Vec<String>> {
    parse_numbered(text).into_iter().map(|(_, cells)| cells).collect()
}

/// [`parse`], but each row carries its **1-based line number in the
/// original text** (comment and blank lines shift data rows, so callers
/// that report errors need the real file line, not the row index).
pub fn parse_numbered(text: &str) -> Vec<(usize, Vec<String>)> {
    text.lines()
        .enumerate()
        .map(|(i, l)| (i + 1, l.trim()))
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'))
        .map(|(lineno, line)| {
            let mut cells: Vec<String> =
                line.split(',').map(|c| c.trim().to_string()).collect();
            // tolerate a single trailing comma (original tool's files)
            if cells.last().is_some_and(|c| c.is_empty()) {
                cells.pop();
            }
            (lineno, cells)
        })
        .collect()
}

/// Incremental csv writer.
pub struct CsvWriter {
    buf: String,
    cols: usize,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        let mut w = CsvWriter { buf: String::new(), cols: header.len() };
        w.push_raw(header.iter().map(|s| s.to_string()).collect());
        w
    }

    fn push_raw(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.cols, "row width mismatch");
        self.buf.push_str(&cells.join(","));
        self.buf.push('\n');
    }

    pub fn row(&mut self, cells: &[String]) {
        self.push_raw(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.push_raw(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn as_str(&self) -> &str {
        &self.buf
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.buf.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_skips_comments_and_blanks() {
        let rows = parse("# hi\n\na, b ,c\n1,2,3,\n");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], vec!["a", "b", "c"]);
        assert_eq!(rows[1], vec!["1", "2", "3"]); // trailing comma dropped
    }

    #[test]
    fn parse_numbered_keeps_file_line_numbers() {
        let rows = parse_numbered("# hi\n\na, b\n# mid\n1,2,\n");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], (3, vec!["a".to_string(), "b".to_string()]));
        assert_eq!(rows[1], (5, vec!["1".to_string(), "2".to_string()]));
    }

    #[test]
    fn writer_round_trips() {
        let mut w = CsvWriter::new(&["x", "y"]);
        w.row(&["1".into(), "2".into()]);
        w.row_display(&[&3u64, &4.5f64]);
        let rows = parse(w.as_str());
        assert_eq!(rows[2], vec!["3", "4.5"]);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn writer_rejects_ragged_rows() {
        let mut w = CsvWriter::new(&["x", "y"]);
        w.row(&["1".into()]);
    }
}
