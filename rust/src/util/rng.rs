//! Deterministic xoshiro256** RNG — std-only substitute for `rand`.
//!
//! Used by the property-test harness, workload generators and the RTL
//! simulator's stimulus. Deterministic seeding keeps every test and bench
//! reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 seed gives a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[lo, hi]` (inclusive). Panics if `lo > hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "bad range [{lo}, {hi}]");
        let span = hi - lo + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64();
        }
        // modulo bias is irrelevant for test-case generation
        lo + self.next_u64() % span
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard-normal-ish f32 (sum of uniforms, CLT) — good enough for
    /// numeric stimulus.
    pub fn normal_f32(&mut self) -> f32 {
        let mut acc = 0.0f32;
        for _ in 0..6 {
            acc += self.f32();
        }
        (acc - 3.0) * (2.0f32).sqrt()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.range(10, 20);
            assert!((10..=20).contains(&v));
        }
    }

    #[test]
    fn range_single_point() {
        let mut r = Rng::new(9);
        assert_eq!(r.range(5, 5), 5);
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_covers_extremes() {
        let mut r = Rng::new(11);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            match r.range(0, 3) {
                0 => saw_lo = true,
                3 => saw_hi = true,
                _ => {}
            }
        }
        assert!(saw_lo && saw_hi);
    }
}
