//! Small self-contained utilities: deterministic RNG, a mini property-test
//! harness (proptest is unavailable offline), a criterion-style bench
//! timer, csv helpers, and a minimal JSON document model (serde is
//! unavailable offline; the serve wire protocol and result store ride on
//! it). Everything here is std-only.

pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Integer square root (floor).
pub fn isqrt(n: u64) -> u64 {
    if n < 2 {
        return n;
    }
    let mut x = (n as f64).sqrt() as u64;
    // fix up float error (checked ops: x*x can overflow near u64::MAX)
    while x.checked_mul(x).is_none_or(|v| v > n) {
        x -= 1;
    }
    while (x + 1).checked_mul(x + 1).is_some_and(|v| v <= n) {
        x += 1;
    }
    x
}

/// Format a byte count human-readably (KB/MB binary).
pub fn fmt_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GiB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MiB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.2} KiB", b / KB)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(u64::MAX, 1), u64::MAX);
    }

    #[test]
    fn isqrt_exact_and_floor() {
        for n in 0..2000u64 {
            let r = isqrt(n);
            assert!(r * r <= n && (r + 1) * (r + 1) > n, "n={n} r={r}");
        }
        assert_eq!(isqrt(16384), 128);
        assert_eq!(isqrt(u64::MAX), u32::MAX as u64);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
