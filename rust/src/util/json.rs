//! Minimal JSON document model (serde is unavailable offline).
//!
//! One [`Json`] value round-trips `parse -> to_string -> parse` exactly.
//! Numbers are kept as their literal text ([`Json::Num`] holds the raw
//! token): serializing a `u64` or `f64` uses Rust's `Display` (shortest
//! round-trip decimal for floats) and parsing recovers the identical
//! value via `str::parse`, so reports persisted by the server's result
//! store come back **bit-identical** — the property the serve protocol
//! and the warm-start cache depend on.
//!
//! Object key order is preserved (insertion order, `Vec`-backed), which
//! keeps emitted wire/store lines stable and diffable.

use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Number as its literal token text (exact round-trip; see module docs).
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    /// Finite floats only: JSON has no NaN/Inf literal.
    pub fn f64(v: f64) -> Json {
        debug_assert!(v.is_finite(), "JSON cannot carry {v}");
        Json::Num(v.to_string())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `as_str`.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    pub fn u64_field(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Json::as_u64)
    }

    pub fn f64_field(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(n),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document (rejects trailing non-whitespace).
    /// Nesting is capped at [`MAX_DEPTH`]: the parser is recursive and
    /// may see untrusted network input, so depth must not be attacker
    /// controlled (a stack overflow aborts the process, not just a
    /// thread).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing bytes at offset {}", p.pos));
        }
        Ok(v)
    }
}

/// Maximum container nesting [`Json::parse`] accepts. Generous for every
/// structure this crate emits (reports nest 4 deep).
pub const MAX_DEPTH: usize = 64;

/// Compact single-line JSON (also provides `.to_string()` via
/// `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| matches!(b, b' ' | b'\t' | b'\n' | b'\r'))
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at offset {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            other => Err(format!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let tok = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("bad number at offset {start}"))?;
        // validate by parsing as f64; the token text is what we keep
        tok.parse::<f64>()
            .map_err(|_| format!("bad number {tok:?} at offset {start}"))?;
        Ok(Json::Num(tok.to_string()))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hi = self.hex4(self.pos + 1)?;
                            if (0xDC00..=0xDFFF).contains(&hi) {
                                return Err("unpaired low surrogate".into());
                            }
                            if (0xD800..=0xDBFF).contains(&hi) {
                                // standard encoders escape non-BMP chars
                                // as a \uD8xx\uDCxx surrogate pair
                                if self.bytes.get(self.pos + 5).copied() != Some(b'\\')
                                    || self.bytes.get(self.pos + 6).copied() != Some(b'u')
                                {
                                    return Err("unpaired high surrogate".into());
                                }
                                let lo = self.hex4(self.pos + 7)?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(code).ok_or("bad surrogate pair")?);
                                self.pos += 10;
                            } else {
                                out.push(char::from_u32(hi).ok_or("bad \\u codepoint")?);
                                self.pos += 4;
                            }
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (multi-byte safe)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let Some(c) = rest.chars().next() else {
                        return Err("unterminated string".into());
                    };
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits starting at byte offset `at`.
    fn hex4(&self, at: usize) -> Result<u32, String> {
        let hex = self
            .bytes
            .get(at..at + 4)
            .ok_or_else(|| "truncated \\u escape".to_string())?;
        let hex = std::str::from_utf8(hex).map_err(|_| "bad \\u escape".to_string())?;
        u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.enter()?;
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']' found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.enter()?;
        self.expect_byte(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(fields));
                }
                other => return Err(format!("expected ',' or '}}' found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_structures() {
        let text = r#"{"a":1,"b":[true,null,"x\n\"y"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(v.u64_field("a"), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().f64_field("d"), Some(-2500.0));
    }

    #[test]
    fn numbers_round_trip_exactly() {
        for x in [0.1f64, 1.0 / 3.0, 6.02214076e23, f64::MIN_POSITIVE, -1234.5678e-9] {
            let j = Json::f64(x);
            let back = Json::parse(&j.to_string()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        for n in [0u64, 7, u64::MAX] {
            assert_eq!(Json::parse(&Json::u64(n).to_string()).unwrap().as_u64(), Some(n));
        }
    }

    #[test]
    fn as_bool_projects_only_booleans() {
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("false").unwrap().as_bool(), Some(false));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
        assert_eq!(Json::parse("\"true\"").unwrap().as_bool(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn depth_is_capped_but_breadth_is_not() {
        // hostile: would overflow the stack without the cap
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).unwrap_err().contains("nesting"));

        // exactly at the cap parses; one past fails
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let too_deep = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&too_deep).is_err());

        // siblings must not accumulate depth
        let wide = format!("[{}]", vec!["[[]]"; 1000].join(","));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn string_escapes_round_trip() {
        let s = "tab\t nl\n quote\" back\\ unicode \u{1f600} ctl\u{1}";
        let j = Json::Str(s.to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn surrogate_pair_escapes_decode() {
        // what a standard encoder (e.g. Python json.dumps) emits for U+1F600
        let escaped = "\"\\ud83d\\ude00\"";
        let v = Json::parse(escaped).unwrap();
        assert_eq!(v.as_str(), Some("\u{1f600}"));
        // lone halves are invalid JSON
        assert!(Json::parse(r#""\ud83d""#).is_err());
        assert!(Json::parse(r#""\ude00""#).is_err());
        assert!(Json::parse(r#""\ud83dA""#).is_err());
    }

    #[test]
    fn field_lookup_misses_cleanly() {
        let v = Json::parse(r#"{"x":1}"#).unwrap();
        assert!(v.get("y").is_none());
        assert!(Json::Null.get("x").is_none());
        assert!(v.get("x").unwrap().as_str().is_none());
    }
}
