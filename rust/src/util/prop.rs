//! Mini property-testing harness (offline stand-in for `proptest`).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs a simple halving shrink over
//! every `u64` field exposed through the [`Shrink`] trait and reports the
//! smallest failing case.

use super::rng::Rng;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller values, tried in order.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let (a, b, c) = self.clone();
        let mut out: Vec<Self> = a
            .shrink()
            .into_iter()
            .map(|x| (x, b.clone(), c.clone()))
            .collect();
        out.extend(b.shrink().into_iter().map(|x| (a.clone(), x, c.clone())));
        out.extend(c.shrink().into_iter().map(|x| (a.clone(), b.clone(), x)));
        out
    }
}

/// Run a property over `cases` random inputs; shrink on failure.
///
/// Returns `Err` describing the minimal counterexample found. Use
/// [`forall`] in tests for the asserting form.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P) -> Result<(), String>
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_to_minimal(input, &prop);
            return Err(format!(
                "property failed (seed={seed}, case={case}); minimal counterexample: {minimal:?}"
            ));
        }
    }
    Ok(())
}

/// Asserting form of [`check`]: fails the calling test with the minimal
/// counterexample message.
pub fn forall<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let outcome = check(seed, cases, gen, prop);
    assert!(outcome.is_ok(), "{}", outcome.unwrap_err());
}

fn shrink_to_minimal<T: Shrink, P: Fn(&T) -> bool>(mut failing: T, prop: &P) -> T {
    // Greedy descent: keep taking the first shrink candidate that still
    // fails, bounded to avoid pathological loops.
    'outer: for _ in 0..10_000 {
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, |r| r.range(0, 1000), |&x| x <= 1000);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics_with_counterexample() {
        forall(2, 500, |r| r.range(0, 1000), |&x| x < 500);
    }

    #[test]
    fn shrink_finds_boundary() {
        // property x < 500 fails first at some x >= 500; shrinking should
        // descend to exactly 500.
        let minimal = shrink_to_minimal(987u64, &|&x: &u64| x < 500);
        assert_eq!(minimal, 500);
    }

    #[test]
    fn tuple_shrink_covers_both_fields() {
        let m = shrink_to_minimal((10u64, 9u64), &|&(a, b): &(u64, u64)| a + b < 5);
        assert_eq!(m.0 + m.1, 5);
    }
}
