//! Bandwidth-constrained execution model — the §III-D question the
//! baseline tool leaves to the reader ("an accelerator design might have
//! multiple processing elements to exploit parallelism, but in reality
//! system memory is unable to supply enough operands to keep all the
//! units busy").
//!
//! SCALE-Sim's core model is stall-free by construction (§III-E); this
//! extension replays the double-buffered fold/fetch schedule against a
//! finite DRAM read bandwidth and computes the *actual* runtime:
//!
//! * fold *i+1*'s operands prefetch during fold *i*'s compute window;
//! * with read bandwidth `B` bytes/cycle the fetch occupies
//!   `ceil(bytes/B)` cycles; any excess beyond the window stalls the
//!   array;
//! * fold 0's (compulsory) fetch is an up-front fill.

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;

use super::{simulate_with, FoldFetch};

/// Runtime under a finite DRAM read bandwidth.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StallReport {
    /// Stall-free (infinite-bandwidth) runtime.
    pub ideal_cycles: u64,
    /// Cycles the array sits idle waiting for operands.
    pub stall_cycles: u64,
    /// The modeled bandwidth (bytes/cycle).
    pub bandwidth: f64,
}

impl StallReport {
    pub fn total_cycles(&self) -> u64 {
        self.ideal_cycles + self.stall_cycles
    }

    /// Slowdown factor vs the stall-free model (>= 1).
    pub fn slowdown(&self) -> f64 {
        self.total_cycles() as f64 / self.ideal_cycles as f64
    }
}

/// Replay one layer's fold/fetch schedule against read bandwidth
/// `bytes_per_cycle`. Panics if the bandwidth is not positive.
pub fn stalled_runtime(
    df: Dataflow,
    layer: &LayerShape,
    cfg: &ArchConfig,
    bytes_per_cycle: f64,
) -> StallReport {
    assert!(bytes_per_cycle > 0.0, "bandwidth must be positive");
    let mut fetches: Vec<FoldFetch> = Vec::new();
    simulate_with(df, layer, cfg, |f| fetches.push(f));

    let mut ideal = 0u64;
    let mut stall = 0u64;
    for (i, f) in fetches.iter().enumerate() {
        ideal += f.cycles;
        let fetch_cycles = (f.bytes as f64 / bytes_per_cycle).ceil() as u64;
        if i == 0 {
            // compulsory up-front fill before the array starts
            stall += fetch_cycles;
        } else {
            // overlapped with the previous fold's compute window
            let window = fetches[i - 1].cycles;
            stall += fetch_cycles.saturating_sub(window);
        }
    }
    StallReport { ideal_cycles: ideal, stall_cycles: stall, bandwidth: bytes_per_cycle }
}

/// The minimum bandwidth at which the layer runs within `tolerance` of
/// stall-free (binary search over the stall model) — a provisioning
/// answer the paper's Fig 7 only gives in average terms.
///
/// Returns `f64::INFINITY` when no finite bandwidth meets the
/// tolerance (the compulsory fold-0 fill stalls at least one cycle at
/// any finite bandwidth, so a tolerance of 0 on a short layer is
/// genuinely unreachable); a finite answer always satisfies the
/// tolerance.
pub fn provision_bandwidth(
    df: Dataflow,
    layer: &LayerShape,
    cfg: &ArchConfig,
    tolerance: f64,
) -> f64 {
    assert!(tolerance >= 0.0);
    let target = 1.0 + tolerance;
    let (mut lo, mut hi) = (1e-3f64, 4096.0f64);
    // Grow the upper bound until it actually meets the tolerance: the
    // historical fixed 4096 B/cyc ceiling was silently returned for
    // layers whose demand exceeds it, fabricating a bandwidth that does
    // not deliver the promised slowdown.
    while stalled_runtime(df, layer, cfg, hi).slowdown() > target {
        if hi >= 1e12 {
            return f64::INFINITY;
        }
        lo = hi;
        hi *= 2.0;
    }
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if stalled_runtime(df, layer, cfg, mid).slowdown() <= target {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn layer() -> LayerShape {
        LayerShape::conv("c", 28, 28, 3, 3, 16, 32, 1)
    }

    fn cfg() -> ArchConfig {
        ArchConfig { array_h: 16, array_w: 16, ..config::paper_default() }
    }

    #[test]
    fn infinite_bandwidth_is_nearly_stall_free() {
        let r = stalled_runtime(Dataflow::Os, &layer(), &cfg(), 1e12);
        assert_eq!(r.ideal_cycles, Dataflow::Os.timing(&layer(), 16, 16).cycles);
        // only the compulsory fill remains (1 cycle at this bandwidth)
        assert!(r.stall_cycles <= 1, "{}", r.stall_cycles);
    }

    #[test]
    fn stalls_grow_monotonically_as_bandwidth_shrinks() {
        let (l, c) = (layer(), cfg());
        let mut last = 0;
        for bw in [64.0, 16.0, 4.0, 1.0, 0.25] {
            let r = stalled_runtime(Dataflow::Os, &l, &c, bw);
            assert!(r.stall_cycles >= last, "bw={bw}");
            last = r.stall_cycles;
        }
        assert!(last > 0, "sub-byte/cycle must stall this layer");
    }

    #[test]
    fn slowdown_at_least_one() {
        for df in Dataflow::ALL {
            let r = stalled_runtime(df, &layer(), &cfg(), 2.0);
            assert!(r.slowdown() >= 1.0, "{df}");
            assert_eq!(r.total_cycles(), r.ideal_cycles + r.stall_cycles);
        }
    }

    #[test]
    fn provisioned_bandwidth_meets_tolerance() {
        let (l, c) = (layer(), cfg());
        for df in Dataflow::ALL {
            let bw = provision_bandwidth(df, &l, &c, 0.05);
            let r = stalled_runtime(df, &l, &c, bw);
            assert!(r.slowdown() <= 1.051, "{df}: {}", r.slowdown());
            // and meaningfully tight: half the bandwidth must violate it
            let r2 = stalled_runtime(df, &l, &c, bw / 4.0);
            assert!(r2.slowdown() > 1.05, "{df}: provisioning not tight");
        }
    }

    #[test]
    fn provisioned_bw_tracks_avg_requirement() {
        // the provisioning answer must be at least the average demand
        let (l, c) = (layer(), cfg());
        let (_, bwreq) = super::super::simulate(Dataflow::Os, &l, &c);
        let prov = provision_bandwidth(Dataflow::Os, &l, &c, 0.05);
        assert!(prov >= bwreq.avg_read_bw * 0.5, "prov={prov} avg={}", bwreq.avg_read_bw);
    }

    #[test]
    #[should_panic(expected = "bandwidth must be positive")]
    fn zero_bandwidth_panics() {
        stalled_runtime(Dataflow::Os, &layer(), &cfg(), 0.0);
    }

    #[test]
    fn provisioning_grows_past_the_historical_ceiling() {
        // 512-byte words push this layer's demand well past 4096 B/cyc:
        // the old fixed ceiling was silently returned even though it
        // delivers a 2.5x slowdown, not the promised 5%
        let l = layer();
        let c = ArchConfig { word_bytes: 512, ..cfg() };
        let at_ceiling = stalled_runtime(Dataflow::Os, &l, &c, 4096.0);
        assert!(at_ceiling.slowdown() > 1.05, "demand must exceed the ceiling");
        let bw = provision_bandwidth(Dataflow::Os, &l, &c, 0.05);
        assert!(bw > 4096.0, "must grow past the old ceiling, got {bw}");
        assert!(bw.is_finite());
        let r = stalled_runtime(Dataflow::Os, &l, &c, bw);
        assert!(r.slowdown() <= 1.051, "{}", r.slowdown());
    }

    #[test]
    fn unreachable_tolerance_surfaces_as_infinity() {
        // the compulsory fill stalls >= 1 cycle at any finite bandwidth,
        // so zero tolerance on a short layer has no finite answer — the
        // miss must be surfaced, not papered over with the ceiling
        let l = LayerShape::gemm("mm", 8, 8, 8);
        let c = ArchConfig { array_h: 8, array_w: 8, ..config::paper_default() };
        let bw = provision_bandwidth(Dataflow::Os, &l, &c, 0.0);
        assert!(bw.is_infinite(), "got {bw}");
    }
}
