//! Scratchpad + DRAM traffic model (§III-C, §III-E steps 3–4).
//!
//! The three SRAM partitions (IFMAP / filter / OFMAP) are double-buffered
//! working sets: while a fold streams from the working set, the idle set
//! prefetches the next fold's operands from DRAM. We simulate that at
//! *fold granularity*: every fold demands operand **segments** (the
//! operand region its mapping touches); a FIFO-resident model per
//! partition decides which demands hit SRAM and which must be fetched
//! from DRAM. Fetches for fold *i* are scheduled during fold *i-1*
//! (double buffering), which yields both total DRAM traffic and the
//! stall-free bandwidth requirement:
//!
//! * `avg_read_bw`  = fetched bytes / runtime — Fig 7's y-axis,
//! * `peak_read_bw` = max over folds of (fetch for next fold / current
//!   fold's cycles) — the burst the interface must sustain.
//!
//! Segment definitions per dataflow (granularity == reuse granularity):
//!
//! | df | IFMAP segment | filter segment |
//! |----|---------------|----------------|
//! | OS | row-fold window region (full-width rows of ifmap) | col-fold filter block (`c_u * K`) |
//! | WS | window-element slice of the whole ifmap (`~ r_u/K`) | fold weight block (`r_u * c_u`, used once) |
//! | IS | window-element slice of the col-fold's px region | element slice of all filters (`Nf * r_u`) |
//!
//! Segments that exceed their partition are streamed through (fetched on
//! every touch, never resident) — the §II-B "spilling" regime. OFMAP
//! traffic: final outputs stream out once; when the window dimension
//! folds and the partial-sum set exceeds the OFMAP partition, partials
//! spill and return (§III-C's second purpose of the output partition).

pub mod stall;

use std::collections::HashMap;
use std::collections::VecDeque;

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;
use crate::trace::fold_schedule;
use crate::util::ceil_div;

/// DRAM traffic in bytes per operand.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DramTraffic {
    pub ifmap_bytes: u64,
    pub filter_bytes: u64,
    /// OFMAP bytes crossing the interface (final writes + partial-sum
    /// spill writes and re-reads).
    pub ofmap_bytes: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.ifmap_bytes + self.filter_bytes + self.ofmap_bytes
    }

    pub fn read_bytes(&self) -> u64 {
        self.ifmap_bytes + self.filter_bytes
    }
}

/// Stall-free DRAM interface requirement (bytes/cycle).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BandwidthReport {
    pub avg_read_bw: f64,
    pub avg_write_bw: f64,
    pub peak_read_bw: f64,
}

/// FIFO-resident segment cache modeling one double-buffered partition.
struct SegCache {
    cap: u64,
    used: u64,
    fifo: VecDeque<u64>,
    resident: HashMap<u64, u64>, // seg id -> bytes
}

impl SegCache {
    fn new(cap: u64) -> Self {
        SegCache { cap, used: 0, fifo: VecDeque::new(), resident: HashMap::new() }
    }

    /// Demand `seg` of `bytes`; returns bytes fetched from DRAM.
    fn touch(&mut self, seg: u64, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        if self.resident.contains_key(&seg) {
            return 0; // hit
        }
        if bytes > self.cap {
            // larger than the partition: stream through, never resident
            return bytes;
        }
        // `used > 0` implies a nonempty fifo; a `while let` makes the
        // loop panic-free even if that invariant were ever violated
        while self.used + bytes > self.cap {
            let Some(victim) = self.fifo.pop_front() else { break };
            self.used -= self.resident.remove(&victim).unwrap_or(0);
        }
        self.resident.insert(seg, bytes);
        self.fifo.push_back(seg);
        self.used += bytes;
        bytes
    }
}

/// Dense FIFO residency for *row-id* segments (OS ifmap path): ids are
/// small integers (ifmap rows), so a stamp vector replaces the hash map
/// of [`SegCache`] — §Perf iteration 3.
struct RowCache {
    cap: u64,
    used: u64,
    row_bytes: u64,
    resident: Vec<bool>,
    fifo: VecDeque<u32>,
}

impl RowCache {
    fn new(cap: u64, row_bytes: u64, rows: u64) -> Self {
        RowCache {
            cap,
            used: 0,
            row_bytes,
            resident: vec![false; rows as usize],
            fifo: VecDeque::new(),
        }
    }

    /// Demand row `y`; returns bytes fetched from DRAM.
    #[inline]
    fn touch(&mut self, y: u64) -> u64 {
        if self.resident[y as usize] {
            return 0;
        }
        if self.row_bytes > self.cap {
            return self.row_bytes; // stream through
        }
        while self.used + self.row_bytes > self.cap {
            let Some(victim) = self.fifo.pop_front() else { break };
            self.resident[victim as usize] = false;
            self.used -= self.row_bytes;
        }
        self.resident[y as usize] = true;
        self.fifo.push_back(y as u32);
        self.used += self.row_bytes;
        self.row_bytes
    }
}

/// IFMAP row span `[y0, y1)` backing output pixels `[p0, p1)` (full-width
/// rows — the prefetcher fetches whole ifmap rows, as the original tool
/// does).
fn ifmap_row_span(layer: &LayerShape, p0: u64, p1: u64) -> (u64, u64) {
    debug_assert!(p0 < p1);
    let ew = layer.ofmap_w();
    let oy0 = p0 / ew;
    let oy1 = (p1 - 1) / ew;
    let y0 = oy0 * layer.stride;
    let y1 = (oy1 * layer.stride + layer.filt_h).min(layer.ifmap_h);
    (y0, y1)
}

/// IFMAP bytes backing output pixels `[p0, p1)`.
fn ifmap_region_bytes(layer: &LayerShape, p0: u64, p1: u64, word: u64) -> u64 {
    let (y0, y1) = ifmap_row_span(layer, p0, p1);
    (y1 - y0) * layer.ifmap_w * layer.channels * word
}

/// Per-fold prefetch demand: compute cycles and DRAM bytes that must
/// arrive before the fold starts (double-buffered during the previous
/// fold's compute window).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldFetch {
    pub cycles: u64,
    pub bytes: u64,
}

/// Simulate the double-buffered memory system for one layer; returns the
/// DRAM traffic and the bandwidth requirement.
pub fn simulate(df: Dataflow, layer: &LayerShape, cfg: &ArchConfig) -> (DramTraffic, BandwidthReport) {
    simulate_with(df, layer, cfg, |_| {})
}

/// [`simulate`] with a per-fold observer (used by the stall model and
/// the DRAM-trace generator).
pub fn simulate_with(
    df: Dataflow,
    layer: &LayerShape,
    cfg: &ArchConfig,
    mut observe: impl FnMut(FoldFetch),
) -> (DramTraffic, BandwidthReport) {
    let word = cfg.word_bytes;
    let (npx, k, nf) = layer.gemm_view();
    let mut ifmap = SegCache::new(cfg.ifmap_sram_bytes());
    let mut ifmap_rows = RowCache::new(
        cfg.ifmap_sram_bytes(),
        layer.ifmap_w * layer.channels * word,
        layer.ifmap_h,
    );
    let mut filter = SegCache::new(cfg.filter_sram_bytes());

    let mut traffic = DramTraffic::default();
    let mut peak = 0f64;
    let mut prev_cycles: Option<u64> = None;
    let mut total_cycles = 0u64;

    for fold in fold_schedule(df, layer, cfg.array_h, cfg.array_w) {
        let fetched = match df {
            Dataflow::Os => {
                // ifmap segments: one per *ifmap row* touched by the
                // fold's window region — row granularity captures the
                // halo reuse between adjacent pixel folds exactly
                let mut fi = 0;
                let (y0, y1) = ifmap_row_span(layer, fold.row_range.0, fold.row_range.1);
                for y in y0..y1 {
                    fi += ifmap_rows.touch(y);
                }
                // filter segment: the col-fold's filter block
                let fseg = fold.col_range.0 / cfg.array_w;
                let fb = fold.c_used * k * word;
                let ff = filter.touch(fseg, fb);
                traffic.ifmap_bytes += fi;
                traffic.filter_bytes += ff;
                fi + ff
            }
            Dataflow::Ws => {
                // ifmap segment: element slice r_used/K of the whole ifmap
                let iseg = fold.row_range.0 / cfg.array_h;
                let ib = ceil_div(layer.ifmap_elems() * fold.r_used, k) * word;
                let fi = ifmap.touch(iseg, ib);
                // weights stream in exactly once; never reused after fill
                let ff = fold.r_used * fold.c_used * word;
                traffic.ifmap_bytes += fi;
                traffic.filter_bytes += ff;
                fi + ff
            }
            Dataflow::Is => {
                // ifmap segment: element slice of this col-fold's px region
                let region = ifmap_region_bytes(layer, fold.col_range.0, fold.col_range.1, word);
                let iseg = fold.col_range.0 / cfg.array_w * 1_000_003
                    + fold.row_range.0 / cfg.array_h;
                let ib = ceil_div(region * fold.r_used, k);
                let fi = ifmap.touch(iseg, ib);
                // filter segment: element slice of all filters
                let fseg = fold.row_range.0 / cfg.array_h;
                let fb = nf * fold.r_used * word;
                let ff = filter.touch(fseg, fb);
                traffic.ifmap_bytes += fi;
                traffic.filter_bytes += ff;
                fi + ff
            }
        };
        // double buffering: this fold's fetch happened during the
        // previous fold's compute window
        if let Some(pc) = prev_cycles {
            peak = peak.max(fetched as f64 / pc as f64);
        }
        prev_cycles = Some(fold.cycles);
        total_cycles += fold.cycles;
        observe(FoldFetch { cycles: fold.cycles, bytes: fetched });
    }

    // OFMAP: final outputs stream out once; spilled partials round-trip.
    let window_folds = match df {
        Dataflow::Os => 1,
        Dataflow::Ws | Dataflow::Is => ceil_div(k, cfg.array_h),
    };
    let ofmap_total = layer.ofmap_elems() * word;
    traffic.ofmap_bytes = if window_folds == 1 {
        ofmap_total
    } else {
        // partial-sum working set per outer fold
        let partial_set = match df {
            Dataflow::Ws => npx * cfg.array_w.min(nf) * word,
            Dataflow::Is => cfg.array_w.min(npx) * nf * word,
            Dataflow::Os => unreachable!(),
        };
        if partial_set <= cfg.ofmap_sram_bytes() {
            ofmap_total
        } else {
            // every window fold writes partials out and all but the
            // first reads them back
            ofmap_total * (2 * window_folds - 1)
        }
    };

    let bw = BandwidthReport {
        avg_read_bw: traffic.read_bytes() as f64 / total_cycles as f64,
        avg_write_bw: traffic.ofmap_bytes as f64 / total_cycles as f64,
        peak_read_bw: peak.max(traffic.read_bytes() as f64 / total_cycles as f64),
    };
    (traffic, bw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn cfg(rows: u64, cols: u64, sram_kb: u64) -> ArchConfig {
        ArchConfig {
            array_h: rows,
            array_w: cols,
            ifmap_sram_kb: sram_kb,
            filter_sram_kb: sram_kb,
            ofmap_sram_kb: sram_kb,
            ..config::paper_default()
        }
    }

    fn layer() -> LayerShape {
        LayerShape::conv("c", 28, 28, 3, 3, 16, 32, 1)
    }

    #[test]
    fn big_sram_fetches_each_operand_exactly_once() {
        let l = layer();
        let (t, _) = simulate(Dataflow::Os, &l, &cfg(16, 16, 2048));
        // whole ifmap fits: every ifmap row fetched exactly once (halo
        // reuse captured by the row-granular resident set)
        assert_eq!(t.filter_bytes, l.filter_elems());
        assert_eq!(t.ifmap_bytes, l.ifmap_elems());
        assert_eq!(t.ofmap_bytes, l.ofmap_elems());
    }

    #[test]
    fn tiny_sram_refetches() {
        let l = layer();
        let big = simulate(Dataflow::Os, &l, &cfg(16, 16, 2048)).0;
        let tiny = simulate(Dataflow::Os, &l, &cfg(16, 16, 1)).0;
        assert!(tiny.total() > big.total(), "tiny={} big={}", tiny.total(), big.total());
    }

    #[test]
    fn traffic_monotonically_nonincreasing_in_sram_size() {
        // Fig 7's premise: more SRAM never increases DRAM traffic.
        let l = layer();
        for df in Dataflow::ALL {
            let mut last = u64::MAX;
            for kb in [1u64, 4, 16, 64, 256, 1024] {
                let t = simulate(df, &l, &cfg(16, 16, kb)).0.total();
                assert!(t <= last, "{df} kb={kb}: {t} > {last}");
                last = t;
            }
        }
    }

    #[test]
    fn ws_weights_cross_dram_exactly_once() {
        let l = layer();
        let (t, _) = simulate(Dataflow::Ws, &l, &cfg(16, 16, 64));
        assert_eq!(t.filter_bytes, l.filter_elems());
    }

    #[test]
    fn bandwidth_consistent_with_traffic() {
        let l = layer();
        let c = cfg(16, 16, 64);
        let (t, bw) = simulate(Dataflow::Os, &l, &c);
        let cycles = Dataflow::Os.timing(&l, 16, 16).cycles;
        let expect = t.read_bytes() as f64 / cycles as f64;
        assert!((bw.avg_read_bw - expect).abs() < 1e-9);
        assert!(bw.peak_read_bw >= bw.avg_read_bw);
    }

    #[test]
    fn ws_partial_spill_when_ofmap_sram_tiny() {
        // K folds + tiny OFMAP partition => spill traffic
        let l = LayerShape::conv("c", 30, 30, 3, 3, 64, 8, 1); // K=576 > 16 rows
        let mut c = cfg(16, 16, 64);
        c.ofmap_sram_kb = 1; // 1KB < Npx*cols bytes
        let spill = simulate(Dataflow::Ws, &l, &c).0.ofmap_bytes;
        c.ofmap_sram_kb = 1024;
        let clean = simulate(Dataflow::Ws, &l, &c).0.ofmap_bytes;
        assert_eq!(clean, l.ofmap_elems());
        assert!(spill > clean);
    }

    #[test]
    fn region_bytes_covers_filter_rows() {
        let l = LayerShape::conv("c", 10, 10, 3, 3, 2, 1, 1);
        // single pixel: 3 ifmap rows of 10px x 2ch
        assert_eq!(ifmap_region_bytes(&l, 0, 1, 1), 3 * 10 * 2);
        // full layer: all 10 rows
        assert_eq!(ifmap_region_bytes(&l, 0, l.npx(), 1), 10 * 10 * 2);
    }
}
