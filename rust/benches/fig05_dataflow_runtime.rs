//! Fig 5: runtime in cycles for all MLPerf workloads under OS/WS/IS on
//! square arrays 128x128 .. 8x8 (five panels a-e).
//!
//! Prints each panel as a table (rows = workloads, cols = dataflows),
//! writes `results/fig05.csv`, and times the full sweep.

use std::path::Path;

use scale_sim::config::{self, workloads};
use scale_sim::sweep::{self, dataflow_sweep};
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;

const ARRAYS: [u64; 5] = [128, 64, 32, 16, 8];

fn main() {
    let base = config::paper_default();
    let topos = workloads::mlperf_suite();
    let threads = sweep::default_threads();

    let pts = dataflow_sweep(&base, &topos, &ARRAYS, threads);
    let mut w = CsvWriter::new(&["workload", "dataflow", "array", "cycles", "utilization"]);
    for p in &pts {
        w.row(&[
            p.workload.clone(),
            p.dataflow.name().to_string(),
            p.array.to_string(),
            p.cycles.to_string(),
            format!("{:.4}", p.utilization),
        ]);
    }
    w.write_to(Path::new("results/fig05.csv")).unwrap();

    for (panel, n) in ARRAYS.iter().enumerate() {
        println!(
            "=== Fig 5({}) runtime [cycles], {}x{} array ===",
            (b'a' + panel as u8) as char,
            n,
            n
        );
        println!("{:<6} {:>14} {:>14} {:>14}  best", "tag", "os", "ws", "is");
        for (tag, name) in workloads::TAGS {
            let row: Vec<u64> = ["os", "ws", "is"]
                .iter()
                .map(|df| {
                    pts.iter()
                        .find(|p| p.workload == name && p.dataflow.name() == *df && p.array == *n)
                        .unwrap()
                        .cycles
                })
                .collect();
            let best = ["os", "ws", "is"][row.iter().enumerate().min_by_key(|(_, c)| **c).unwrap().0];
            println!("{:<6} {:>14} {:>14} {:>14}  {}", tag, row[0], row[1], row[2], best);
        }
        println!();
    }

    bench_auto("fig05/full_sweep(7wl x 3df x 5arrays)", std::time::Duration::from_secs(3), || {
        dataflow_sweep(&base, &topos, &ARRAYS, threads).len()
    });
    println!("fig05 OK -> results/fig05.csv");
}
