//! Fig 5: runtime in cycles for all MLPerf workloads under OS/WS/IS on
//! square arrays 128x128 .. 8x8 (five panels a-e), through the engine's
//! memoizing sweep grid.
//!
//! Prints each panel as a table (rows = workloads, cols = dataflows),
//! writes `results/fig05.csv` + `results/BENCH_fig05_sweep.json`
//! (wall-clock and cache hit-rate), and times the full sweep cold vs
//! warm.

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;
use scale_sim::Dataflow;

const ARRAYS: [u64; 5] = [128, 64, 32, 16, 8];

fn main() {
    let topos = workloads::mlperf_suite();
    let engine = Engine::builder().build().unwrap();

    let out = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&ARRAYS)
        .run();
    let mut w = CsvWriter::new(&["workload", "dataflow", "array", "cycles", "utilization"]);
    for p in &out.points {
        w.row(&[
            p.workload.clone(),
            p.dataflow.name().to_string(),
            p.array_h.to_string(),
            p.report.total_cycles().to_string(),
            format!("{:.4}", p.report.overall_utilization(p.total_pes())),
        ]);
    }
    w.write_to(Path::new("results/fig05.csv")).unwrap();

    for (panel, n) in ARRAYS.iter().enumerate() {
        println!(
            "=== Fig 5({}) runtime [cycles], {}x{} array ===",
            (b'a' + panel as u8) as char,
            n,
            n
        );
        println!("{:<6} {:>14} {:>14} {:>14}  best", "tag", "os", "ws", "is");
        for (tag, name) in workloads::TAGS {
            let row: Vec<u64> = Dataflow::ALL
                .iter()
                .map(|&df| out.find(name, df, *n, *n).unwrap().report.total_cycles())
                .collect();
            let best =
                ["os", "ws", "is"][row.iter().enumerate().min_by_key(|(_, c)| **c).unwrap().0];
            println!("{:<6} {:>14} {:>14} {:>14}  {}", tag, row[0], row[1], row[2], best);
        }
        println!();
    }

    println!(
        "sweep: {} points, {} layer sims, {} cache hits ({:.1}% hit rate), {:.1} ms",
        out.stats.points,
        out.stats.memo.layer_sims,
        out.stats.memo.cache_hits,
        out.stats.hit_rate() * 100.0,
        out.stats.wall.as_secs_f64() * 1e3
    );
    // distinct name from the CLI's repo-root BENCH_sweep.json so the two
    // perf artifacts never shadow each other
    out.stats.write_bench_json(Path::new("results/BENCH_fig05_sweep.json")).unwrap();

    // cold engine each iteration vs re-running on the warm shared cache
    bench_auto("fig05/full_sweep_cold(7wl x 3df x 5arrays)", std::time::Duration::from_secs(3), || {
        let cold = Engine::builder().build().unwrap();
        cold.sweep()
            .workloads(&topos)
            .dataflows(&Dataflow::ALL)
            .square_arrays(&ARRAYS)
            .run()
            .points
            .len()
    });
    bench_auto("fig05/full_sweep_warm(memoized)", std::time::Duration::from_secs(1), || {
        engine
            .sweep()
            .workloads(&topos)
            .dataflows(&Dataflow::ALL)
            .square_arrays(&ARRAYS)
            .run()
            .points
            .len()
    });
    println!("fig05 OK -> results/fig05.csv, results/BENCH_fig05_sweep.json");
}
