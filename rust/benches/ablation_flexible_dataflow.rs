//! Ablation: flexible (per-layer best) dataflow vs fixed dataflows —
//! the quantitative answer to §IV-B question 3 ("Are we missing out a
//! lot by employing fixed dataflows? Or is there a dataflow which works
//! in all cases?") and the FlexFlow-motivated design question, run
//! through the engine's memoized flexible study.
//!
//! Paper's conclusion to reproduce: "fixating to a given dataflow might
//! not lead to significant losses" — flexible speedup over the best
//! fixed dataflow should be modest, while the penalty for freezing the
//! *wrong* dataflow can be large.

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;

fn main() {
    let mut w = CsvWriter::new(&[
        "workload", "array", "os_cycles", "ws_cycles", "is_cycles", "flexible_cycles",
        "speedup_vs_best", "speedup_vs_worst",
    ]);
    for &n in &[128u64, 32, 8] {
        println!("== flexible vs fixed dataflow, {n}x{n} array ==");
        println!(
            "{:<14} {:>14} {:>14} {:>14} {:>14} {:>9} {:>9}  wins(os/ws/is)",
            "workload", "os", "ws", "is", "flexible", "vs_best", "vs_worst"
        );
        let engine = Engine::builder().array(n, n).build().unwrap();
        for (_, name) in workloads::TAGS {
            let topo = workloads::builtin(name).unwrap();
            let r = engine.flexible_study(&topo);
            let [os, ws, is] = r.fixed_cycles;
            println!(
                "{:<14} {:>14} {:>14} {:>14} {:>14} {:>9.3} {:>9.3}  {:?}",
                name, os, ws, is, r.flexible_cycles,
                r.speedup_over_best_fixed(),
                r.speedup_over_worst_fixed(),
                r.wins()
            );
            w.row(&[
                name.to_string(),
                n.to_string(),
                os.to_string(),
                ws.to_string(),
                is.to_string(),
                r.flexible_cycles.to_string(),
                format!("{:.4}", r.speedup_over_best_fixed()),
                format!("{:.4}", r.speedup_over_worst_fixed()),
            ]);
        }
        println!();
    }
    w.write_to(Path::new("results/ablation_flexible_dataflow.csv")).unwrap();

    let engine = Engine::builder().build().unwrap();
    let topo = workloads::builtin("resnet50").unwrap();
    bench_auto("ablation/flexible_study(resnet50)", std::time::Duration::from_secs(2), || {
        engine.flexible_study(&topo).flexible_cycles
    });
    println!("ablation_flexible_dataflow OK -> results/ablation_flexible_dataflow.csv");
}
