//! Fig 8: runtime vs array aspect ratio at fixed 16384 PEs, shapes
//! 8x2048 .. 2048x8, panels (a) OS, (b) WS, (c) IS.
//!
//! Findings to reproduce: dataflow x shape interact dramatically; square
//! aspect ratios perform well for the common case; specific workloads
//! (W4, W7) prefer different corners under different dataflows.

use std::path::Path;

use scale_sim::config::{self, workloads};
use scale_sim::dataflow::Dataflow;
use scale_sim::sweep::{self, fig8_shapes, shape_sweep};
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;

fn main() {
    let base = config::paper_default();
    let topos = workloads::mlperf_suite();
    let threads = sweep::default_threads();
    let shapes = fig8_shapes();

    let pts = shape_sweep(&base, &topos, &shapes, threads);
    let mut w = CsvWriter::new(&["workload", "dataflow", "rows", "cols", "cycles"]);
    for p in &pts {
        w.row(&[
            p.workload.clone(),
            p.dataflow.name().to_string(),
            p.rows.to_string(),
            p.cols.to_string(),
            p.cycles.to_string(),
        ]);
    }
    w.write_to(Path::new("results/fig08.csv")).unwrap();

    for (panel, df) in Dataflow::ALL.iter().enumerate() {
        println!(
            "=== Fig 8({}) runtime [cycles] vs shape, {} dataflow, 16384 PEs ===",
            (b'a' + panel as u8) as char,
            df
        );
        print!("{:<14}", "workload");
        for (r, c) in &shapes {
            print!(" {:>12}", format!("{r}x{c}"));
        }
        println!("  best");
        for (_, name) in workloads::TAGS {
            let series: Vec<u64> = shapes
                .iter()
                .map(|(r, c)| {
                    pts.iter()
                        .find(|p| {
                            p.workload == name && p.dataflow == *df && p.rows == *r && p.cols == *c
                        })
                        .unwrap()
                        .cycles
                })
                .collect();
            let best = series.iter().enumerate().min_by_key(|(_, c)| **c).unwrap().0;
            print!("{name:<14}");
            for v in &series {
                print!(" {v:>12}");
            }
            println!("  {}x{}", shapes[best].0, shapes[best].1);
        }
        println!();
    }

    bench_auto("fig08/shape_sweep(7wl x 3df x 9shapes)", std::time::Duration::from_secs(3), || {
        shape_sweep(&base, &topos, &shapes, threads).len()
    });
    println!("fig08 OK -> results/fig08.csv");
}
