//! Fig 8: runtime vs array aspect ratio at fixed 16384 PEs, shapes
//! 8x2048 .. 2048x8, panels (a) OS, (b) WS, (c) IS, through the engine's
//! memoizing sweep grid.
//!
//! Findings to reproduce: dataflow x shape interact dramatically; square
//! aspect ratios perform well for the common case; specific workloads
//! (W4, W7) prefer different corners under different dataflows.

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::sweep::fig8_shapes;
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;
use scale_sim::Dataflow;

fn main() {
    let topos = workloads::mlperf_suite();
    let shapes = fig8_shapes();
    let engine = Engine::builder().build().unwrap();

    let out = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .array_shapes(&shapes)
        .run();
    let mut w = CsvWriter::new(&["workload", "dataflow", "rows", "cols", "cycles"]);
    for p in &out.points {
        w.row(&[
            p.workload.clone(),
            p.dataflow.name().to_string(),
            p.array_h.to_string(),
            p.array_w.to_string(),
            p.report.total_cycles().to_string(),
        ]);
    }
    w.write_to(Path::new("results/fig08.csv")).unwrap();

    for (panel, df) in Dataflow::ALL.iter().enumerate() {
        println!(
            "=== Fig 8({}) runtime [cycles] vs shape, {} dataflow, 16384 PEs ===",
            (b'a' + panel as u8) as char,
            df
        );
        print!("{:<14}", "workload");
        for (r, c) in &shapes {
            print!(" {:>12}", format!("{r}x{c}"));
        }
        println!("  best");
        for (_, name) in workloads::TAGS {
            let series: Vec<u64> = shapes
                .iter()
                .map(|&(r, c)| out.find(name, *df, r, c).unwrap().report.total_cycles())
                .collect();
            let best = series.iter().enumerate().min_by_key(|(_, c)| **c).unwrap().0;
            print!("{name:<14}");
            for v in &series {
                print!(" {v:>12}");
            }
            println!("  {}x{}", shapes[best].0, shapes[best].1);
        }
        println!();
    }

    println!(
        "sweep: {} layer sims, {} cache hits ({:.1}% hit rate)",
        out.stats.memo.layer_sims,
        out.stats.memo.cache_hits,
        out.stats.hit_rate() * 100.0
    );
    bench_auto("fig08/shape_sweep(7wl x 3df x 9shapes)", std::time::Duration::from_secs(3), || {
        let cold = Engine::builder().build().unwrap();
        cold.sweep()
            .workloads(&topos)
            .dataflows(&Dataflow::ALL)
            .array_shapes(&shapes)
            .run()
            .points
            .len()
    });
    println!("fig08 OK -> results/fig08.csv");
}
