//! Fig 4: validation — cycle counts from every engine backend (RTL
//! PE-grid, trace-driven, analytical) for Mat-Mat multiplications sized
//! to the array (OS dataflow), through the same `Engine` entry point.
//!
//! Prints the paper's series (size -> cycles per backend; they must
//! tally exactly), writes `results/fig04.csv`, and times both the
//! analytical model and the RTL substrate.

use std::path::Path;

use scale_sim::dataflow::Dataflow;
use scale_sim::engine::{BackendKind, Engine};
use scale_sim::util::bench::{bench, black_box};
use scale_sim::util::csv::CsvWriter;
use scale_sim::{rtl, LayerShape};

fn main() {
    println!("=== Fig 4: engine backends, array-sized MatMul (OS) ===");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>7}",
        "size", "rtl_cycles", "trace_cycles", "sim_cycles", "match"
    );
    let mut w = CsvWriter::new(&["size", "rtl_cycles", "trace_cycles", "sim_cycles"]);
    for n in [4u64, 8, 16, 32, 64, 128] {
        let layer = LayerShape::gemm("mm", n, n, n);
        let cycles: Vec<u64> = BackendKind::ALL
            .iter()
            .map(|&kind| {
                Engine::builder()
                    .dataflow(Dataflow::Os)
                    .array(n, n)
                    .backend(kind)
                    .build()
                    .unwrap()
                    .run_layer(&layer)
                    .timing
                    .cycles
            })
            .collect();
        let (model, trace, rtl_c) = (cycles[0], cycles[1], cycles[2]);
        // cross-check the engine's RTL backend against a direct RTL run
        let (a, b) = rtl::random_matrices(n as usize, n as usize, n as usize, n);
        let direct = rtl::run_matmul(&a, &b, n as usize, n as usize, n as usize);
        let ok = model == trace && trace == rtl_c && rtl_c == direct.cycles;
        println!("{:>6} {:>12} {:>12} {:>12} {:>7}", n, rtl_c, trace, model, ok);
        assert!(ok, "validation must be cycle-exact at {n}");
        w.row(&[n.to_string(), rtl_c.to_string(), trace.to_string(), model.to_string()]);
    }
    w.write_to(Path::new("results/fig04.csv")).unwrap();

    // timing: RTL cost vs analytical cost (the paper's speed argument
    // for an analytical simulator over RTL simulation)
    let (a, b) = rtl::random_matrices(32, 32, 32, 7);
    bench("fig04/rtl_32x32_matmul", 2, 10, || black_box(rtl::run_matmul(&a, &b, 32, 32, 32).cycles));
    let layer = LayerShape::gemm("mm", 32, 32, 32);
    bench("fig04/analytical_32x32", 10, 100, || {
        black_box(Dataflow::Os.timing(&layer, 32, 32).cycles)
    });
    println!("fig04 OK -> results/fig04.csv");
}
