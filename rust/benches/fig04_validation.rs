//! Fig 4: validation — SCALE-Sim cycle counts vs the RTL model for
//! Mat-Mat multiplications sized to the array (OS dataflow).
//!
//! Prints the paper's series (size -> cycles for both platforms; they
//! must tally exactly), writes `results/fig04.csv`, and times both the
//! analytical model and the RTL substrate.

use std::path::Path;

use scale_sim::dataflow::Dataflow;
use scale_sim::util::bench::{bench, black_box};
use scale_sim::util::csv::CsvWriter;
use scale_sim::{rtl, LayerShape};

fn main() {
    println!("=== Fig 4: RTL vs SCALE-Sim cycles, array-sized MatMul (OS) ===");
    println!("{:>6} {:>12} {:>12} {:>7}", "size", "rtl_cycles", "sim_cycles", "match");
    let mut w = CsvWriter::new(&["size", "rtl_cycles", "sim_cycles"]);
    for n in [4usize, 8, 16, 32, 64, 128] {
        let (a, b) = rtl::random_matrices(n, n, n, n as u64);
        let r = rtl::run_matmul(&a, &b, n, n, n);
        let layer = LayerShape::gemm("mm", n as u64, n as u64, n as u64);
        let model = Dataflow::Os.timing(&layer, n as u64, n as u64).cycles;
        println!("{:>6} {:>12} {:>12} {:>7}", n, r.cycles, model, r.cycles == model);
        assert_eq!(r.cycles, model, "validation must be cycle-exact");
        w.row(&[n.to_string(), r.cycles.to_string(), model.to_string()]);
    }
    w.write_to(Path::new("results/fig04.csv")).unwrap();

    // timing: RTL cost vs analytical cost (the paper's speed argument
    // for an analytical simulator over RTL simulation)
    let (a, b) = rtl::random_matrices(32, 32, 32, 7);
    bench("fig04/rtl_32x32_matmul", 2, 10, || black_box(rtl::run_matmul(&a, &b, 32, 32, 32).cycles));
    let layer = LayerShape::gemm("mm", 32, 32, 32);
    bench("fig04/analytical_32x32", 10, 100, || {
        black_box(Dataflow::Os.timing(&layer, 32, 32).cycles)
    });
    println!("fig04 OK -> results/fig04.csv");
}
