//! Fig 6: energy (mJ) split into compute and memory transfers, for all
//! workloads x dataflows x square arrays 128x128 .. 8x8, through the
//! engine's memoizing sweep grid.
//!
//! Absolute joules depend on our documented per-access constants
//! (DESIGN.md §3, the paper publishes none); the comparison *shape*
//! (which dataflow is cheapest, compute-vs-memory split) is the target.

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;
use scale_sim::Dataflow;

const ARRAYS: [u64; 5] = [128, 64, 32, 16, 8];

fn main() {
    let topos = workloads::mlperf_suite();
    let engine = Engine::builder().build().unwrap();

    let out = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&ARRAYS)
        .run();
    let mut w =
        CsvWriter::new(&["workload", "dataflow", "array", "compute_mj", "memory_mj", "total_mj"]);
    for p in &out.points {
        let e = p.report.total_energy();
        w.row(&[
            p.workload.clone(),
            p.dataflow.name().to_string(),
            p.array_h.to_string(),
            format!("{:.6}", e.compute_mj),
            format!("{:.6}", e.memory_mj()),
            format!("{:.6}", e.total_mj()),
        ]);
    }
    w.write_to(Path::new("results/fig06.csv")).unwrap();

    for (panel, n) in ARRAYS.iter().enumerate() {
        println!(
            "=== Fig 6({}) energy [mJ] (compute+memory), {}x{} array ===",
            (b'a' + panel as u8) as char,
            n,
            n
        );
        println!("{:<6} {:>16} {:>16} {:>16}  best", "tag", "os", "ws", "is");
        for (tag, name) in workloads::TAGS {
            let row: Vec<f64> = Dataflow::ALL
                .iter()
                .map(|&df| out.find(name, df, *n, *n).unwrap().report.total_energy().total_mj())
                .collect();
            let best_i = row
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            println!(
                "{:<6} {:>16.4} {:>16.4} {:>16.4}  {}",
                tag, row[0], row[1], row[2],
                ["os", "ws", "is"][best_i]
            );
        }
        println!();
    }

    println!(
        "sweep: {} layer sims, {} cache hits ({:.1}% hit rate)",
        out.stats.memo.layer_sims,
        out.stats.memo.cache_hits,
        out.stats.hit_rate() * 100.0
    );
    bench_auto("fig06/energy_sweep", std::time::Duration::from_secs(3), || {
        let cold = Engine::builder().build().unwrap();
        cold.sweep()
            .workloads(&topos)
            .dataflows(&Dataflow::ALL)
            .square_arrays(&[32])
            .run()
            .points
            .len()
    });
    println!("fig06 OK -> results/fig06.csv");
}
