//! Fig 6: energy (mJ) split into compute and memory transfers, for all
//! workloads x dataflows x square arrays 128x128 .. 8x8.
//!
//! Absolute joules depend on our documented per-access constants
//! (DESIGN.md §3, the paper publishes none); the comparison *shape*
//! (which dataflow is cheapest, compute-vs-memory split) is the target.

use std::path::Path;

use scale_sim::config::{self, workloads};
use scale_sim::sweep::{self, dataflow_sweep};
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;

const ARRAYS: [u64; 5] = [128, 64, 32, 16, 8];

fn main() {
    let base = config::paper_default();
    let topos = workloads::mlperf_suite();
    let threads = sweep::default_threads();

    let pts = dataflow_sweep(&base, &topos, &ARRAYS, threads);
    let mut w =
        CsvWriter::new(&["workload", "dataflow", "array", "compute_mj", "memory_mj", "total_mj"]);
    for p in &pts {
        w.row(&[
            p.workload.clone(),
            p.dataflow.name().to_string(),
            p.array.to_string(),
            format!("{:.6}", p.energy_compute_mj),
            format!("{:.6}", p.energy_memory_mj),
            format!("{:.6}", p.energy_compute_mj + p.energy_memory_mj),
        ]);
    }
    w.write_to(Path::new("results/fig06.csv")).unwrap();

    for (panel, n) in ARRAYS.iter().enumerate() {
        println!(
            "=== Fig 6({}) energy [mJ] (compute+memory), {}x{} array ===",
            (b'a' + panel as u8) as char,
            n,
            n
        );
        println!("{:<6} {:>16} {:>16} {:>16}  best", "tag", "os", "ws", "is");
        for (tag, name) in workloads::TAGS {
            let row: Vec<f64> = ["os", "ws", "is"]
                .iter()
                .map(|df| {
                    let p = pts
                        .iter()
                        .find(|p| p.workload == name && p.dataflow.name() == *df && p.array == *n)
                        .unwrap();
                    p.energy_compute_mj + p.energy_memory_mj
                })
                .collect();
            let best_i = row
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            println!(
                "{:<6} {:>16.4} {:>16.4} {:>16.4}  {}",
                tag, row[0], row[1], row[2],
                ["os", "ws", "is"][best_i]
            );
        }
        println!();
    }

    bench_auto("fig06/energy_sweep", std::time::Duration::from_secs(3), || {
        dataflow_sweep(&base, &topos, &[32], threads).len()
    });
    println!("fig06 OK -> results/fig06.csv");
}
