//! GEMM workload suite through the typed-IR front end: runs the built-in
//! GEMM workloads (mlp / attention / lstm / ncf_gemm) on the memoizing
//! grid together with conv-encoded NCF, demonstrating the conv <-> GEMM
//! lowered-tile cache sharing the workload IR enables.
//!
//! Writes `results/BENCH_gemm_suite.json` (wall-clock, cache hit rate)
//! and prints per-workload cycle tables for all three dataflows.

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::util::bench::bench;
use scale_sim::Dataflow;

const ARRAYS: [u64; 3] = [128, 64, 32];

fn main() {
    let engine = Engine::builder().build().unwrap();
    let specs = workloads::gemm_suite();

    let out = engine
        .sweep()
        .workloads(&[workloads::builtin("ncf").unwrap()])
        .workload_specs(&specs)
        .unwrap()
        .dataflows(&Dataflow::ALL)
        .square_arrays(&ARRAYS)
        .run();

    println!("{:<12} {:>4} {:>6} {:>14} {:>8}", "workload", "df", "array", "cycles", "util%");
    for p in &out.points {
        println!(
            "{:<12} {:>4} {:>6} {:>14} {:>8.2}",
            p.workload,
            p.dataflow.name(),
            p.array_h,
            p.report.total_cycles(),
            p.report.overall_utilization(p.total_pes()) * 100.0
        );
    }
    println!(
        "grid: {} points, {} layer sims, {} cache hits ({:.1}% hit rate; ncf_gemm replays \
         conv-encoded ncf entirely from cache)",
        out.stats.points,
        out.stats.memo.layer_sims,
        out.stats.memo.cache_hits,
        out.stats.hit_rate() * 100.0
    );
    out.stats
        .write_bench_json(Path::new("results/BENCH_gemm_suite.json"))
        .unwrap();
    println!("wrote results/BENCH_gemm_suite.json");

    // warm rerun wall-clock: the whole suite from the memo table
    bench("gemm_suite_warm_rerun", 1, 5, || {
        engine
            .sweep()
            .workloads(&[workloads::builtin("ncf").unwrap()])
            .workload_specs(&workloads::gemm_suite())
            .unwrap()
            .dataflows(&Dataflow::ALL)
            .square_arrays(&ARRAYS)
            .run()
            .points
            .len()
    });
}
