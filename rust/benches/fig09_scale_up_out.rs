//! Fig 9: ratio of runtime on a scaled-up array vs a scaled-out (8x8
//! nodes) implementation with equal total PEs, per dataflow, PE budgets
//! 64 .. 16384 (x4 per step), through the engine façade.
//!
//! Findings to reproduce: scale-up wins the common case
//! (ratio < 1), but specific workloads flip the decision — "scaling
//! decision to be tied to workloads" (§IV-E).

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::scaleout::PE_SWEEP;
use scale_sim::sweep::{self, parallel_map};
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;
use scale_sim::Dataflow;

fn main() {
    let topos = workloads::mlperf_suite();
    let threads = sweep::default_threads();
    let engines: Vec<(Dataflow, Engine)> = Dataflow::ALL
        .iter()
        .map(|&df| (df, Engine::builder().dataflow(df).build().unwrap()))
        .collect();

    let mut jobs = Vec::new();
    for t in &topos {
        for (df, engine) in &engines {
            for pe in PE_SWEEP {
                jobs.push((t, *df, engine, pe));
            }
        }
    }
    let rows = parallel_map(&jobs, threads, |&(t, df, engine, pe)| {
        (t.name.clone(), df, pe, engine.compare_scaling(&t.layers, pe))
    });

    let mut w = CsvWriter::new(&["workload", "dataflow", "pes", "up_cycles", "out_cycles", "ratio"]);
    for (name, df, pe, c) in &rows {
        w.row(&[
            name.clone(),
            df.name().to_string(),
            pe.to_string(),
            c.up_cycles.to_string(),
            c.out_cycles.to_string(),
            format!("{:.4}", c.runtime_ratio()),
        ]);
    }
    w.write_to(Path::new("results/fig09.csv")).unwrap();

    for (panel, df) in Dataflow::ALL.iter().enumerate() {
        println!(
            "=== Fig 9({}) runtime(up)/runtime(out), {} dataflow (ratio>1 => scale-out wins) ===",
            (b'a' + panel as u8) as char,
            df
        );
        print!("{:<14}", "workload");
        for pe in PE_SWEEP {
            print!(" {pe:>9}");
        }
        println!();
        for (_, name) in workloads::TAGS {
            print!("{name:<14}");
            for pe in PE_SWEEP {
                let c = &rows
                    .iter()
                    .find(|(n, d, p, _)| n == name && d == df && *p == pe)
                    .unwrap()
                    .3;
                print!(" {:>9.3}", c.runtime_ratio());
            }
            println!();
        }
        println!();
    }

    let os_engine = &engines[0].1;
    bench_auto("fig09/scale_sweep", std::time::Duration::from_secs(3), || {
        os_engine.compare_scaling(&topos[0].layers, 16384).up_cycles
    });
    println!("fig09 OK -> results/fig09.csv");
}
