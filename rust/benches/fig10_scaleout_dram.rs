//! Fig 10: ratio of DRAM bandwidth requirement for *weight* matrices,
//! scale-up vs scale-out, per layer, for AlphaGoZero (W1, panels a-c)
//! and DeepSpeech2 (W2, panels d-f) under OS / WS / IS, through the
//! engine façade.
//!
//! Findings to reproduce: most W1 layers favor scale-up at small PE
//! counts with the trend shifting as PEs grow; IS reverses the trend;
//! IS on W2 strongly favors scale-up.

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;
use scale_sim::Dataflow;

const PES: [u64; 4] = [256, 1024, 4096, 16384];

fn main() {
    let mut w = CsvWriter::new(&["workload", "dataflow", "layer", "pes", "weight_bw_ratio"]);

    for (panel_base, wl) in [("a-c", "alphagozero"), ("d-f", "deepspeech2")] {
        let topo = workloads::builtin(wl).unwrap();
        for df in Dataflow::ALL {
            println!(
                "=== Fig 10({panel_base}/{df}) weight-DRAM-bw ratio up/out, {wl} (ratio<1 => scale-up cheaper) ==="
            );
            print!("{:<16}", "layer");
            for pe in PES {
                print!(" {pe:>9}");
            }
            println!();
            let engine = Engine::builder().dataflow(df).build().unwrap();
            for layer in &topo.layers {
                print!("{:<16}", layer.name);
                for pe in PES {
                    let c = engine.compare_scaling(std::slice::from_ref(layer), pe);
                    let r = c.weight_bw_ratio();
                    print!(" {r:>9.3}");
                    w.row(&[
                        wl.to_string(),
                        df.name().to_string(),
                        layer.name.clone(),
                        pe.to_string(),
                        format!("{r:.4}"),
                    ]);
                }
                println!();
            }
            println!();
        }
    }
    w.write_to(Path::new("results/fig10.csv")).unwrap();

    let topo = workloads::builtin("alphagozero").unwrap();
    let engine = Engine::builder().build().unwrap();
    bench_auto("fig10/per_layer_compare(W1)", std::time::Duration::from_secs(2), || {
        topo.layers
            .iter()
            .map(|l| engine.compare_scaling(std::slice::from_ref(l), 16384).weight_bw_ratio())
            .sum::<f64>()
    });
    println!("fig10 OK -> results/fig10.csv");
}
