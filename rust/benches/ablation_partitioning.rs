//! Ablation: scale-out partitioning strategies (§IV-E's "alternate
//! partitioning strategies exist, and in fact the best strategy may
//! differ from layer to layer depending on the number of filters vs
//! channels").
//!
//! Compares output-channel vs pixel vs auto (per-layer best)
//! partitioning for the scale-out side at 16384 PEs (256 nodes) and
//! reports the runtime and the weight-duplication cost.
#![allow(deprecated)] // scale_out_point is a pinned legacy shim

use std::path::Path;

use scale_sim::config::{self, workloads};
use scale_sim::scaleout::{scale_out_point, Partition, NODE_PES};
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;

fn main() {
    let base = config::paper_default();
    let nodes = 16384 / NODE_PES;
    let mut w = CsvWriter::new(&[
        "workload", "channels_cycles", "pixels_cycles", "auto_cycles", "channels_wbytes",
        "pixels_wbytes",
    ]);
    println!("== scale-out partitioning at 16384 PEs ({nodes} nodes of 8x8, os) ==");
    println!(
        "{:<14} {:>14} {:>14} {:>14} {:>9} {:>16} {:>16}",
        "workload", "channels", "pixels", "auto", "auto_gain", "w_bytes(chan)", "w_bytes(px)"
    );
    for (_, name) in workloads::TAGS {
        let topo = workloads::builtin(name).unwrap();
        let mut totals = [0u64; 3];
        let mut wbytes = [0u64; 2];
        for layer in &topo.layers {
            for (i, p) in Partition::ALL.iter().enumerate() {
                let (c, wb) = scale_out_point(&base, layer, nodes, *p);
                totals[i] += c;
                if i < 2 {
                    wbytes[i] += wb;
                }
            }
        }
        let gain = totals[0].min(totals[1]) as f64 / totals[2] as f64;
        println!(
            "{:<14} {:>14} {:>14} {:>14} {:>9.3} {:>16} {:>16}",
            name, totals[0], totals[1], totals[2], gain, wbytes[0], wbytes[1]
        );
        w.row(&[
            name.to_string(),
            totals[0].to_string(),
            totals[1].to_string(),
            totals[2].to_string(),
            wbytes[0].to_string(),
            wbytes[1].to_string(),
        ]);
    }
    w.write_to(Path::new("results/ablation_partitioning.csv")).unwrap();

    let topo = workloads::builtin("resnet50").unwrap();
    bench_auto("ablation/partitioning(resnet50)", std::time::Duration::from_secs(2), || {
        topo.layers
            .iter()
            .map(|l| scale_out_point(&base, l, nodes, Partition::Auto).0)
            .sum::<u64>()
    });
    println!("ablation_partitioning OK -> results/ablation_partitioning.csv");
}
