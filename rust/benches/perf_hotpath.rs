//! §Perf harness: micro-benchmarks of the simulator's hot paths, used to
//! drive the optimization pass recorded in EXPERIMENTS.md §Perf.
//!
//! * analytical timing (closed form)        — should be O(1)/layer
//! * fold schedule iteration                — O(#folds)
//! * memory/double-buffer simulation        — O(#folds + rows touched)
//! * full-trace generation + summarize      — O(#SRAM events), the
//!   dominant cost when dumping traces (§III-E step 1)
//! * full MLPerf suite through the engine   — the end-to-end L3 metric,
//!   cold cache vs memoized
//! * RTL cycle-level simulation             — the substrate we beat

use std::time::Duration;

use scale_sim::config::{self, workloads, ArchConfig};
use scale_sim::engine::Engine;
use scale_sim::trace;
use scale_sim::util::bench::{bench, bench_auto, black_box};
use scale_sim::{rtl, Dataflow, LayerShape};

fn main() {
    let cfg = config::paper_default();
    let layer = LayerShape::conv("conv3x3_256", 30, 30, 3, 3, 256, 256, 1);

    bench("perf/analytical_timing(conv)", 100, 1000, || {
        black_box(Dataflow::Os.timing(&layer, 128, 128).cycles)
    });

    bench_auto("perf/fold_schedule(8x8,conv)", Duration::from_secs(1), || {
        trace::fold_schedule(Dataflow::Os, &layer, 8, 8).map(|f| f.cycles).sum::<u64>()
    });

    let small = ArchConfig { array_h: 8, array_w: 8, ..cfg.clone() };
    bench_auto("perf/memory_simulate(8x8,conv)", Duration::from_secs(1), || {
        scale_sim::memory::simulate(Dataflow::Os, &layer, &small).0.total()
    });

    for df in Dataflow::ALL {
        bench_auto(
            &format!("perf/trace_summarize({df},16x16,conv)"),
            Duration::from_secs(2),
            || {
                let c = ArchConfig { array_h: 16, array_w: 16, ..cfg.clone() };
                trace::summarize(df, &layer, &c).cycles()
            },
        );
    }

    let topos = workloads::mlperf_suite();
    bench("perf/mlperf_suite_cold(128x128,os)", 1, 5, || {
        let engine = Engine::new(cfg.clone());
        topos.iter().map(|t| engine.run_topology(t).total_cycles()).sum::<u64>()
    });
    let warm = Engine::new(cfg.clone());
    for t in &topos {
        warm.run_topology(t); // populate the memo cache
    }
    bench("perf/mlperf_suite_warm(memoized)", 1, 5, || {
        topos.iter().map(|t| warm.run_topology(t).total_cycles()).sum::<u64>()
    });
    bench("perf/mlperf_dataflow_sweep_cold", 1, 5, || {
        let engine = Engine::new(cfg.clone());
        engine
            .sweep()
            .workloads(&topos)
            .dataflows(&Dataflow::ALL)
            .square_arrays(&[128, 8])
            .run()
            .points
            .len()
    });
    println!(
        "perf/warm_cache: {} entries, {:.1}% lifetime hit rate",
        warm.cache_entries(),
        warm.cache_stats().hit_rate() * 100.0
    );

    let (a, b) = rtl::random_matrices(64, 64, 64, 1);
    bench("perf/rtl_64x64", 1, 5, || black_box(rtl::run_matmul(&a, &b, 64, 64, 64).cycles));

    println!("perf_hotpath OK");
}
