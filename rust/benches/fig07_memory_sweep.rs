//! Fig 7: required DRAM bandwidth vs scratchpad size for stall-free
//! operation — (a) all workloads, (b) AlphaGoZero, (c) NCF,
//! (d) SentimentCNN — sweeping 32KB..2048KB per operand buffer through
//! the engine's memoizing sweep grid.
//!
//! The paper's findings to reproduce: diminishing returns near 1MB for
//! the common case (a); W1's knee at ~256KB (b); W4's knee at very small
//! sizes (c); W6 still improving past 1024KB (d).

use std::path::Path;

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::util::bench::bench_auto;
use scale_sim::util::csv::CsvWriter;

const SIZES: [u64; 7] = [32, 64, 128, 256, 512, 1024, 2048];

fn main() {
    let topos = workloads::mlperf_suite();
    let engine = Engine::builder().build().unwrap();

    let out = engine.sweep().workloads(&topos).sram_sizes_kb(&SIZES).run();
    let mut w = CsvWriter::new(&["workload", "sram_kb", "avg_read_bw", "dram_bytes"]);
    for p in &out.points {
        w.row(&[
            p.workload.clone(),
            p.ifmap_sram_kb.to_string(),
            format!("{:.5}", p.report.avg_dram_read_bw()),
            p.report.total_dram().total().to_string(),
        ]);
    }
    w.write_to(Path::new("results/fig07.csv")).unwrap();

    println!("=== Fig 7: stall-free DRAM read bandwidth [bytes/cycle] vs scratchpad size ===");
    print!("{:<14}", "workload");
    for s in SIZES {
        print!(" {s:>9}K");
    }
    println!("  knee");
    for (_, name) in workloads::TAGS {
        let series: Vec<f64> = SIZES
            .iter()
            .map(|s| {
                out.points
                    .iter()
                    .find(|p| p.workload == name && p.ifmap_sram_kb == *s)
                    .unwrap()
                    .report
                    .avg_dram_read_bw()
            })
            .collect();
        // knee = first size where the next doubling gains < 5%
        let knee = SIZES
            .iter()
            .zip(series.windows(2))
            .find(|(_, w)| w[0] / w[1].max(1e-12) < 1.05)
            .map(|(s, _)| format!("{s}K"))
            .unwrap_or_else(|| ">2048K".into());
        print!("{name:<14}");
        for v in &series {
            print!(" {v:>10.4}");
        }
        println!("  {knee}");
    }

    println!(
        "sweep: {} layer sims, {} cache hits ({:.1}% hit rate)",
        out.stats.memo.layer_sims,
        out.stats.memo.cache_hits,
        out.stats.hit_rate() * 100.0
    );
    bench_auto("fig07/memory_sweep(7wl x 7sizes)", std::time::Duration::from_secs(3), || {
        let cold = Engine::builder().build().unwrap();
        cold.sweep().workloads(&topos).sram_sizes_kb(&SIZES).run().points.len()
    });
    println!("fig07 OK -> results/fig07.csv");
}
