#!/usr/bin/env bash
# CI entry point: build + test + CLI smoke. Mirrors the tier-1 gate
# (ROADMAP.md) and exercises the engine end-to-end:
#   - `scale-sim run -t resnet50`    — full workload through the engine
#   - `scale-sim validate --max 16`  — Fig-4 cycle-exactness across all
#                                      three backends (analytical/trace/rtl)
#   - `scale-sim sweep dataflow -t ncf` — memoizing grid smoke; emits
#                                      BENCH_sweep.json (wall-clock +
#                                      cache hit-rate) for the perf log.
set -euo pipefail
cd "$(dirname "$0")"

echo "== build =="
cargo build --release

echo "== test =="
cargo test -q

BIN=target/release/scale-sim

echo "== smoke: run resnet50 =="
"$BIN" run -t resnet50 > /dev/null
echo "ok"

echo "== smoke: validate (Fig 4, all backends) =="
"$BIN" validate --max 16

echo "== smoke: sweep (memoizing grid + BENCH_sweep.json) =="
"$BIN" sweep dataflow -t ncf > /dev/null
test -f BENCH_sweep.json
cat BENCH_sweep.json

echo "CI OK"
