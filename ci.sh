#!/usr/bin/env bash
# CI entry point: build + test + CLI smoke. Mirrors the tier-1 gate
# (ROADMAP.md) and exercises the engine end-to-end:
#   - `scale-sim run -t resnet50`    — full workload through the engine
#   - `scale-sim validate --max 16`  — Fig-4 cycle-exactness across all
#                                      three backends (analytical/trace/rtl)
#   - `scale-sim sweep dataflow -t ncf` — memoizing grid smoke; emits
#                                      BENCH_sweep.json (wall-clock +
#                                      cache hit-rate) for the perf log.
#   - serve smoke: start the TCP job server on an ephemeral port with a
#     state dir, one client round trip, a /stats check, clean protocol
#     shutdown (queue drained + store flushed).
#   - dse smoke: tiny multi-array campaign through `scale-sim dse run`
#     (nodes/partitions axes), a simulated kill (--max-points) +
#     `dse resume`, byte-identical `dse report` frontiers, and a >=50%
#     cache hit rate on the resumed half.
#   - scaleout smoke: `scale-sim scaleout` renders the Fig 9/10 table
#     and BENCH_scaleout.json carries nodes/partition fields.
#   - profile smoke: `scale-sim profile` renders the per-layer span
#     table and writes a Chrome trace + Prometheus metrics snapshot
#     (docs/OBSERVABILITY.md); the serve smoke also scrapes
#     `client metrics` for the queue/worker/cache series.
# The default `cargo test -q` tier includes the golden regression
# suites (rust/tests/golden.rs: timings + scaleout fixtures), the
# workload-IR and scaleout property suites, and the server stress
# suite; a test-inventory floor guards against suites silently
# dropping out of the run.
set -euo pipefail
cd "$(dirname "$0")"

echo "== build =="
cargo build --release

echo "== fmt =="
if cargo fmt --version > /dev/null 2>&1; then
  cargo fmt --check
else
  echo "rustfmt unavailable in this toolchain; skipped"
fi

echo "== clippy =="
if cargo clippy --version > /dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable in this toolchain; skipped"
fi

echo "== lint (in-tree static analysis, ratcheted by lint.baseline) =="
# hard gate: any finding not enumerated in lint.baseline fails, and so
# does any stale baseline entry — the accepted-violation count can only
# ratchet down. See README ("scale-sim lint") and docs/INVARIANTS.md.
# The pass is also wall-clock budgeted: the interprocedural rules
# (call graph + fixpoint, R6-R8) must stay cheap enough to run on
# every commit, or the gate gets skipped in practice.
LINT_BUDGET_MS=10000
LINT_T0=$(date +%s%3N)
target/release/scale-sim lint --root .
LINT_MS=$(( $(date +%s%3N) - LINT_T0 ))
echo "lint wall time: ${LINT_MS}ms (budget ${LINT_BUDGET_MS}ms)"
if [ "$LINT_MS" -gt "$LINT_BUDGET_MS" ]; then
  echo "lint blew its wall-clock budget (${LINT_MS}ms > ${LINT_BUDGET_MS}ms)"
  exit 1
fi

echo "== test =="
TEST_LOG=$(mktemp)
cargo test -q 2>&1 | tee "$TEST_LOG"

echo "== test-inventory floor =="
# every `cargo test -q` result line reports "N passed"; the sum across
# binaries must not drop below the checked-in floor — a suite falling
# out of Cargo.toml (or a mass #[ignore]) fails here even though every
# remaining test is green. Raise the floor as suites grow.
TEST_FLOOR=520
TOTAL_PASSED=$(grep -o '[0-9]\+ passed' "$TEST_LOG" | awk '{s+=$1} END {print s+0}')
rm -f "$TEST_LOG"
echo "total tests passed: $TOTAL_PASSED (floor $TEST_FLOOR)"
if [ "$TOTAL_PASSED" -lt "$TEST_FLOOR" ]; then
  echo "test inventory shrank below the floor ($TOTAL_PASSED < $TEST_FLOOR): a suite is not running"
  exit 1
fi

BIN=target/release/scale-sim

echo "== smoke: run resnet50 =="
"$BIN" run -t resnet50 > /dev/null
echo "ok"

echo "== smoke: machine-readable run reports (--format json|csv) =="
"$BIN" run -t ncf --format json | grep -q '"total_cycles"'
"$BIN" run -t ncf --format csv | head -1 | grep -q '^layer,cycles,'
echo "ok"

echo "== smoke: validate (Fig 4, all backends) =="
"$BIN" validate --max 16

echo "== smoke: every topology csv (conv + gemm) through validate --workload =="
for f in topologies/*.csv topologies/gemm/*.csv; do
  "$BIN" validate --workload "$f"
done

echo "== smoke: GEMM workload end-to-end on all three backends =="
for b in analytical trace rtl; do
  "$BIN" run -t topologies/gemm/mlp.csv --backend "$b" --array 32x32 > /dev/null
done
echo "ok"

echo "== smoke: sweep (memoizing grid + BENCH_sweep.json) =="
"$BIN" sweep dataflow -t ncf > /dev/null
test -f BENCH_sweep.json
cat BENCH_sweep.json

echo "== smoke: conv <-> gemm lowered-tile cache sharing =="
# ncf (conv-encoded) and ncf_gemm (GEMM csv) lower to identical tiles:
# sweeping both must serve the second workload entirely from the memo
# cache, which shows up as a >=50% hit rate in BENCH_sweep.json
"$BIN" sweep dataflow -t ncf --workload topologies/gemm/ncf_gemm.csv > /dev/null
HIT=$(grep -o '"cache_hit_rate": *[0-9.e-]*' BENCH_sweep.json | grep -o '[0-9.e-]*$')
awk -v h="$HIT" 'BEGIN { exit (h >= 0.5) ? 0 : 1 }' \
  || { echo "conv<->gemm cache sharing broken: hit rate $HIT"; exit 1; }
echo "ok (hit rate $HIT)"

echo "== smoke: help lists the serve + dse + scaleout subcommands =="
for sub in serve client bench-serve dse scaleout lint profile; do
  "$BIN" --help | grep -q "scale-sim $sub" || { echo "missing $sub in --help"; exit 1; }
done
echo "ok"

echo "== smoke: profile (span table + Chrome trace + metrics snapshot) =="
PROF=$(mktemp -d)
"$BIN" profile -t topologies/alexnet.csv --dram-bw 16 \
  --trace-out "$PROF/trace.json" --metrics-out "$PROF/metrics.prom" \
  --bench "$PROF/BENCH_profile.json" > "$PROF/table.txt"
grep -q "TOTAL:" "$PROF/table.txt" || { echo "profile table lacks TOTAL"; exit 1; }
grep -q '"traceEvents"' "$PROF/trace.json" || { echo "trace is not Chrome trace JSON"; exit 1; }
grep -q 'scale_sim_cache_misses_total' "$PROF/metrics.prom" \
  || { echo "metrics snapshot lacks cache series"; exit 1; }
grep -q '"total_cycles"' "$PROF/BENCH_profile.json"
rm -rf "$PROF"
echo "ok"

echo "== smoke: scaleout (Fig 9/10 table + BENCH_scaleout.json) =="
"$BIN" scaleout -t ncf --partition auto > scaleout_smoke.txt
grep -q "Fig 9" scaleout_smoke.txt || { echo "Fig 9 table missing"; exit 1; }
rm -f scaleout_smoke.txt
test -f BENCH_scaleout.json
grep -q '"nodes"' BENCH_scaleout.json || { echo "BENCH_scaleout.json lacks nodes"; exit 1; }
grep -q '"partition":"auto"' BENCH_scaleout.json || { echo "BENCH_scaleout.json lacks partition"; exit 1; }
grep -q '"interconnect_avg_bw"' BENCH_scaleout.json
cat BENCH_scaleout.json | head -c 300; echo
echo "ok"

echo "== smoke: scaleout --fabric (route-aware interconnect + BENCH_fabric.json) =="
# the route-aware fabric study: flat (legacy baseline) vs line vs mesh
# at the same node counts; the JSON must carry per-link peak/avg
# throughput, stall cycles and banked-DRAM row-buffer stats, and a bad
# bandwidth figure must be rejected at the flag, not by a stall assert
"$BIN" scaleout -t ncf --budgets 1024 --fabric flat,line,mesh \
  --link-bw 8 --dram-bw 16 > /dev/null
test -f BENCH_fabric.json
for field in '"fabric":"mesh"' '"stall_cycles"' '"max_link_peak_bw"' \
             '"hop_bytes"' '"dram_row_hit_rate"' '"link_bw":8'; do
  grep -q "$field" BENCH_fabric.json \
    || { echo "BENCH_fabric.json lacks $field"; exit 1; }
done
if "$BIN" scaleout -t ncf --fabric line --dram-bw 0 > /dev/null 2>&1; then
  echo "scaleout accepted --dram-bw 0"; exit 1
fi
if "$BIN" scaleout -t ncf --fabric torus > /dev/null 2>&1; then
  echo "scaleout accepted an unknown fabric"; exit 1
fi
cat BENCH_fabric.json | head -c 300; echo
echo "ok"

echo "== smoke: dse campaign (multi-array axes, run, kill+resume, frontier identity, cache hit rate) =="
DSE_A=$(mktemp -d)
DSE_B=$(mktemp -d)
# 2 dataflows x 2 arrays x 2 nodes x 2 partitions x 2 bandwidths on ncf
cat > "$DSE_A/spec.json" <<'EOF'
{"name":"ci","workloads":["ncf"],"dataflows":["os","ws"],"arrays":["16x16","32x32"],"nodes":[1,4],"partitions":["channels","auto"],"sram_kb":[64],"dram_bw":[4,16],"energy":"28nm"}
EOF
"$BIN" dse run --spec "$DSE_A/spec.json" --state-dir "$DSE_A/state" \
  --bench "$DSE_A/BENCH_dse.json" > "$DSE_A/full.txt"
grep -q "Pareto frontier — runtime vs energy" "$DSE_A/full.txt"
grep -q "x 2 nodes x 2 partitions" "$DSE_A/full.txt" || { echo "dse summary lacks multi axes"; exit 1; }
# interrupted twin: stop after half the grid ("kill"), then resume
"$BIN" dse run --spec "$DSE_A/spec.json" --state-dir "$DSE_B/state" --max-points 16 \
  > "$DSE_B/cut.txt"
grep -q "campaign incomplete" "$DSE_B/cut.txt"
"$BIN" dse resume --state-dir "$DSE_B/state" --bench "$DSE_B/BENCH_dse.json" > /dev/null
# frontier identity: both journals must print byte-identical reports
"$BIN" dse report --state-dir "$DSE_A/state" > "$DSE_A/report.txt"
"$BIN" dse report --state-dir "$DSE_B/state" > "$DSE_B/report.txt"
cmp "$DSE_A/report.txt" "$DSE_B/report.txt" \
  || { echo "kill+resume frontier differs from uninterrupted run"; exit 1; }
grep -q '"frontier_runtime_energy"' "$DSE_B/BENCH_dse.json"
# the resumed half must be served >=50% from the shared/warm caches
HIT=$(grep -o '"cache_hit_rate": *[0-9.e-]*' "$DSE_B/BENCH_dse.json" | grep -o '[0-9.e-]*$')
awk -v h="$HIT" 'BEGIN { exit (h >= 0.5) ? 0 : 1 }' \
  || { echo "resumed dse half hit rate $HIT < 0.5"; exit 1; }
cat "$DSE_B/BENCH_dse.json"
rm -rf "$DSE_A" "$DSE_B"
echo "ok (resumed-half hit rate $HIT)"

echo "== smoke: serve round trip (server + client + /stats + shutdown) =="
SERVE_STATE=$(mktemp -d)
SERVE_LOG=$(mktemp)
"$BIN" serve --addr 127.0.0.1:0 --state-dir "$SERVE_STATE" --cache-stripes 8 > "$SERVE_LOG" &
SERVE_PID=$!
trap 'kill ${SERVE_PID:-} ${FED_A_PID:-} ${FED_B_PID:-} 2>/dev/null || true; rm -rf "$SERVE_STATE" "$SERVE_LOG" "${FED_LOG_A:-}" "${FED_LOG_B:-}"' EXIT
for _ in $(seq 1 100); do
  grep -q "^listening on " "$SERVE_LOG" && break
  sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$SERVE_LOG")
test -n "$ADDR" || { echo "server never reported its address"; cat "$SERVE_LOG"; exit 1; }

"$BIN" client run --addr "$ADDR" -t ncf | tail -1 | grep -q '"event":"done"'
# GEMM workloads run through the server too (lowered client-side from the
# GEMM csv; the ncf_gemm tiles hit the entries ncf just populated)
"$BIN" client run --addr "$ADDR" -t topologies/gemm/ncf_gemm.csv | tail -1 | grep -q '"event":"done"'
"$BIN" client stats --addr "$ADDR" | grep -q '"queue_depth"'
"$BIN" client stats --addr "$ADDR" | grep -q '"workers_busy"'
"$BIN" client stats --addr "$ADDR" | grep -q '"cache_hits"'
# Prometheus scrape over the wire: cache + queue + worker series
"$BIN" client metrics --addr "$ADDR" > metrics_smoke.prom
grep -q 'scale_sim_queue_depth' metrics_smoke.prom || { echo "scrape lacks queue series"; exit 1; }
grep -q 'scale_sim_workers_busy' metrics_smoke.prom || { echo "scrape lacks worker series"; exit 1; }
grep -q '# TYPE scale_sim_cache_hits_total counter' metrics_smoke.prom \
  || { echo "scrape lacks cache series"; exit 1; }
rm -f metrics_smoke.prom
# batch envelope: two workloads in one request; the interleaved stream
# must end with the envelope's batch_done tally
"$BIN" client batch --addr "$ADDR" -t ncf -t topologies/gemm/mlp.csv > batch_smoke.txt
tail -1 batch_smoke.txt | grep -q '"event":"batch_done"' || { echo "batch_done missing"; cat batch_smoke.txt; exit 1; }
tail -1 batch_smoke.txt | grep -q '"jobs":2' || { echo "batch_done lacks jobs tally"; exit 1; }
grep -q '"id":1,"event":"done"' batch_smoke.txt || { echo "batch sub-job 1 never finished"; exit 1; }
grep -q '"id":2,"event":"done"' batch_smoke.txt || { echo "batch sub-job 2 never finished"; exit 1; }
rm -f batch_smoke.txt
"$BIN" client shutdown --addr "$ADDR" | grep -q '"event":"shutting_down"'
wait "$SERVE_PID"
test -f "$SERVE_STATE/results.jsonl" || { echo "store was not flushed on shutdown"; exit 1; }

# warm restart: the flushed store must pre-warm the next server life
"$BIN" serve --addr 127.0.0.1:0 --state-dir "$SERVE_STATE" > "$SERVE_LOG" &
SERVE_PID=$!
for _ in $(seq 1 100); do
  grep -q "^listening on " "$SERVE_LOG" && break
  sleep 0.1
done
ADDR=$(sed -n 's/^listening on //p' "$SERVE_LOG")
test -n "$ADDR" || { echo "restarted server never reported its address"; cat "$SERVE_LOG"; exit 1; }
"$BIN" client stats --addr "$ADDR" | grep -q '"warm_entries"'
"$BIN" client run --addr "$ADDR" -t ncf > /dev/null
"$BIN" client stats --addr "$ADDR" | grep -q '"warm_hits":[1-9]' \
  || { echo "warm restart served no warm hits"; exit 1; }
"$BIN" client shutdown --addr "$ADDR" > /dev/null
wait "$SERVE_PID"
echo "ok"

echo "== smoke: federation (2 instances, --peers, cross-instance cache sharing) =="
# a mutual two-member fleet needs both addresses up front (ring
# agreement is by construction from the listed strings), so these use
# fixed loopback ports instead of :0
FED_A=127.0.0.1:7471
FED_B=127.0.0.1:7472
FED_LOG_A=$(mktemp)
FED_LOG_B=$(mktemp)
"$BIN" serve --addr "$FED_A" --peers "$FED_B" > "$FED_LOG_A" &
FED_A_PID=$!
"$BIN" serve --addr "$FED_B" --peers "$FED_A" > "$FED_LOG_B" &
FED_B_PID=$!
for log in "$FED_LOG_A" "$FED_LOG_B"; do
  for _ in $(seq 1 100); do
    grep -q "^listening on " "$log" && break
    sleep 0.1
  done
  grep -q "^listening on " "$log" || { echo "federated server never came up"; cat "$log"; exit 1; }
  grep -q "^federated: 1 peer" "$log" || { echo "server did not report its ring"; cat "$log"; exit 1; }
done
# run on A: A computes its self-owned keys and fetches B-owned keys
# from B, so B's memo cache fills with its share of the workload
"$BIN" client run --addr "$FED_A" -t resnet50 | tail -1 | grep -q '"event":"done"'
"$BIN" client stats --addr "$FED_B" | grep -q '"layer_sims":[1-9]' \
  || { echo "no keys routed to the peer"; exit 1; }
# replay on B: B's share is now warm locally and A's share is warm on
# A, so the fleet re-serves the workload from its ONE logical cache
"$BIN" client run --addr "$FED_B" -t resnet50 | tail -1 | grep -q '"event":"done"'
"$BIN" client stats --addr "$FED_B" | grep -q '"cache_hits":[1-9]' \
  || { echo "cross-instance warm replay missed the shared cache"; exit 1; }
"$BIN" client shutdown --addr "$FED_A" > /dev/null
"$BIN" client shutdown --addr "$FED_B" > /dev/null
wait "$FED_A_PID" "$FED_B_PID"
rm -f "$FED_LOG_A" "$FED_LOG_B"
echo "ok"

echo "== bench-serve (closed-loop load, gated against BENCH_serve.baseline.json) =="
# a pinned mixed run+sweep load; the binary itself enforces the gate:
# fail if throughput < 0.8x baseline or p99 > 2x baseline. A missing
# baseline (first run) is blessed; refresh deliberately with --bless.
"$BIN" bench-serve --clients 8 --rounds 1 --workers 4 \
  --baseline BENCH_serve.baseline.json
test -f BENCH_serve.json
grep -q '"busy_retries"' BENCH_serve.json || { echo "BENCH_serve.json lacks shed accounting"; exit 1; }
test -f BENCH_serve.baseline.json
cat BENCH_serve.json
echo "ok"

echo "CI OK"
